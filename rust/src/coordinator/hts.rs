//! **HTS-RL** — the paper's system (§4.1, Fig. 1e, Fig. 2d).
//!
//! Topology per run:
//!   * `n_envs / K` executor threads, each owning a pool of K environment
//!     replicas (`executor::ReplicaPool`, DESIGN.md §6). Every replica
//!     keeps three private PRNG streams (env dynamics, sampling seeds,
//!     step-time delays), its own batch columns, and its own rollout
//!     stripe. The pool interleaves its replicas: observations go out
//!     with executor-drawn seeds, actions come back through non-blocking
//!     mailbox polls, and injected engine latency is a virtual deadline
//!     the scheduler overlaps instead of a `thread::sleep` — **no lock,
//!     no shared state of any kind on the step path** (DESIGN.md §5),
//!     and no thread ever idles on one replica's inference round-trip
//!     while a sibling replica could run.
//!   * `n_actors` actor threads (usually fewer than executors): batch-grab
//!     observations, forward once per batch on their private PJRT runtime,
//!     sample with the executor-provided seeds, post actions back.
//!   * one learner (this thread): trains on the *read* storage — data
//!     collected last iteration with θ_{j-1} — computing the gradient at
//!     θ_{j-1} and applying it to θ_j (Eq. 6), concurrently with the
//!     executors filling the write storage.
//!
//! The swap barrier is two-phase (see `buffers::double`): the learner
//! gathers all stripes into the `[T, B]` train view and publishes
//! parameters while all pool threads are parked, which upholds the
//! full-determinism guarantee for any actor count *and any replica
//! pooling factor* (paper Tab. 4; `rust/tests/pool.rs`).

use std::sync::Arc;

use anyhow::Result;

use super::common::{spawn_actors, EvalWorker, RunConfig};
use crate::buffers::{ActionBuffer, RolloutStorage, StateBuffer, StripedSwap};
use crate::executor::{PoolReport, PoolShared, ReplicaPool};
use crate::metrics::report::{SpsMeter, Stopwatch, TrainReport};
use crate::model::manifest::Manifest;
use crate::model::ParamStore;
use crate::runtime::{ModelRuntime, Trainer};

pub fn run_hts(cfg: &RunConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    let info = manifest.model(&cfg.spec.model)?.clone();
    let b_cols = cfg.batch_columns();
    let alpha = cfg.alpha(info.unroll);
    anyhow::ensure!(
        alpha % info.unroll == 0,
        "sync interval {alpha} must be a multiple of unroll {}",
        info.unroll
    );
    let k = cfg.replicas_per_executor.max(1);
    anyhow::ensure!(
        cfg.n_envs % k == 0,
        "n_envs {} must be divisible by replicas_per_executor {k}",
        cfg.n_envs
    );
    let n_threads = cfg.n_envs / k;

    // Learner-side runtime, initial parameters, trainer.
    let rt = ModelRuntime::new(manifest.clone())?;
    let init = rt.init_params(&cfg.spec.model, cfg.seed)?;
    let mut trainer =
        Trainer::new(&rt, &cfg.spec.model, cfg.algo, init.clone(), b_cols)?;

    // Shared system state: one stripe per *replica*, one barrier party
    // per pool *thread*.
    let dp = Arc::new(StripedSwap::with_parties(
        alpha,
        b_cols,
        info.obs_dim,
        cfg.n_envs,
        n_threads,
    ));
    let state_buf = Arc::new(StateBuffer::with_telemetry(cfg.telemetry));
    let act_buf = Arc::new(ActionBuffer::new(b_cols));
    let params = Arc::new(ParamStore::new(init.clone()));
    let sps = Arc::new(SpsMeter::new());
    let watch = Stopwatch::new();
    // Optional event tracing (DESIGN.md §15): one sink shared by every
    // thread; per-thread rings are owned outright, deposited at exit.
    let trace_sink = cfg.trace_mode().map(crate::trace::TraceSink::new);

    // ---- executors (replica pools) ---------------------------------------
    // Episode logs and trajectory signatures are thread-local and merged
    // at join (no shared episode lock — DESIGN.md §6).
    let mut exec_handles = Vec::new();
    for t in 0..n_threads {
        let spec = cfg.spec.clone();
        let shared = PoolShared {
            swap: dp.clone(),
            state_buf: state_buf.clone(),
            act_buf: act_buf.clone(),
            sps: sps.clone(),
            watch,
            col_offset: 0,
            telemetry: cfg.telemetry,
            trace: trace_sink.clone(),
        };
        let seed = cfg.seed;
        exec_handles.push(std::thread::spawn(move || -> Result<PoolReport> {
            let replicas = t * k..(t + 1) * k;
            ReplicaPool::new(&spec, seed, alpha, replicas, shared)?.run()
        }));
    }

    // ---- actors ------------------------------------------------------------
    let actor_handles = spawn_actors(
        cfg.n_actors,
        cfg.spec.model.clone(),
        cfg.artifacts.clone(),
        state_buf.clone(),
        act_buf.clone(),
        params.clone(),
        b_cols,
        cfg.telemetry,
        trace_sink.as_ref(),
    );

    // ---- evaluation worker -------------------------------------------------
    let eval = if cfg.eval_every > 0 {
        Some(EvalWorker::spawn(
            cfg.artifacts.clone(),
            cfg.spec.clone(),
            cfg.eval_episodes,
            cfg.seed ^ 0xe7a1,
        ))
    } else {
        None
    };

    // ---- learner (this thread) ----------------------------------------------
    // `gathered` is the learner-owned read storage: refilled zero-alloc
    // from the replica stripes at each swap barrier, then consumed
    // concurrently with the executors filling the next iteration.
    let mut gathered = RolloutStorage::new(alpha, b_cols, info.obs_dim);
    let mut behavior: Arc<Vec<f32>> = Arc::new(init);
    let mut learner_tr = crate::trace::TraceScope::from_sink(
        trace_sink.as_ref(),
        crate::trace::Role::Learner,
        0,
    );
    let mut it = 0u64;
    let mut last_out = Default::default();
    loop {
        if it >= 1 {
            // data collected in iteration it-1, gathered at the barrier
            last_out = trainer.step(&gathered, &behavior)?;
            if let Some(ev) = &eval {
                if trainer.updates % cfg.eval_every.max(1) == 0 {
                    ev.submit(
                        trainer.updates,
                        sps.steps(),
                        &watch,
                        Arc::new(trainer.params.clone()),
                    );
                }
            }
        }
        // Phase 1: wait for all pool threads to park (all obs answered,
        // no in-flight inference).
        learner_tr.begin(crate::trace::Kind::LearnerWait, 0);
        let up = dp.learner_arrive(it);
        learner_tr.end(crate::trace::Kind::LearnerWait, 0);
        if !up {
            break;
        }
        // Exclusive publication window: gather the stripes into the
        // [T, B] train view (fixed column order — deterministic),
        // remember the parameters that collected it (θ_{j-1}), then
        // publish θ_j for the executors' next iteration.
        learner_tr.begin(crate::trace::Kind::Gather, 0);
        dp.gather_and_reset(&mut gathered);
        learner_tr.end(crate::trace::Kind::Gather, 0);
        behavior = params.latest().data.clone();
        params.publish(trainer.params.clone());
        if cfg.stop.done(sps.steps(), watch.elapsed_s(), trainer.updates) {
            dp.shutdown();
            state_buf.close();
            act_buf.close();
            break;
        }
        it = dp.learner_release(it);
    }

    // Merge the thread-local episode logs and XOR-combine the per-replica
    // trajectory signatures (combine order independent — DESIGN.md §6).
    let mut episodes = Vec::new();
    let mut signature = 0u64;
    let mut tel = crate::telemetry::TelemetryScope::new(false);
    for h in exec_handles {
        let report = h.join().expect("executor panicked")?;
        signature ^= report.signature;
        episodes.extend(report.episodes);
        tel.merge(&report.telemetry);
    }
    for h in actor_handles {
        let scope = h.join().expect("actor panicked")?;
        tel.merge(&scope);
    }
    tel.merge(&state_buf.telemetry());
    learner_tr.deposit();

    let evals = match eval {
        Some(ev) => {
            // final snapshot for the last policy
            ev.submit(
                trainer.updates,
                sps.steps(),
                &watch,
                Arc::new(trainer.params.clone()),
            );
            ev.finish()?
        }
        None => Vec::new(),
    };

    episodes.sort_by_key(|e| e.steps);

    Ok(TrainReport {
        method: "hts".into(),
        env: cfg.spec.name.clone(),
        seed: cfg.seed,
        steps: sps.steps(),
        updates: trainer.updates,
        wall_s: watch.elapsed_s(),
        episodes,
        evals,
        signature,
        staleness: vec![1.0], // guaranteed lag of one (paper §4.1)
        final_loss: last_out.total_loss,
        final_entropy: last_out.entropy,
        telemetry: cfg.telemetry.then(|| tel.report()),
        trace: trace_sink.as_ref().map(|s| s.report()),
    })
}
