//! **HTS-RL** — the paper's system (§4.1, Fig. 1e, Fig. 2d).
//!
//! Topology per run:
//!   * `n_envs` executor threads, each owning one environment replica and
//!     three private PRNG streams (env dynamics, sampling seeds, step-time
//!     delays). Executors push `(obs, slot, seed)` to the state buffer,
//!     block on their action mailbox, apply the action, and write the
//!     transition into their private column stripe — **no lock, no shared
//!     state of any kind on the step path** (DESIGN.md §5).
//!   * `n_actors` actor threads (usually fewer than executors): batch-grab
//!     observations, forward once per batch on their private PJRT runtime,
//!     sample with the executor-provided seeds, post actions back.
//!   * one learner (this thread): trains on the *read* storage — data
//!     collected last iteration with θ_{j-1} — computing the gradient at
//!     θ_{j-1} and applying it to θ_j (Eq. 6), concurrently with the
//!     executors filling the write storage.
//!
//! The swap barrier is two-phase (see `buffers::double`): the learner
//! gathers all stripes into the `[T, B]` train view and publishes
//! parameters while all executors are parked, which upholds the
//! full-determinism guarantee for any actor count (paper Tab. 4).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::common::{spawn_actors, EvalWorker, Fnv, RunConfig};
use crate::buffers::{
    ActionBuffer, ObsMsg, RolloutStorage, StateBuffer, StripedSwap,
};
use crate::metrics::report::{EpisodePoint, SpsMeter, Stopwatch, TrainReport};
use crate::model::manifest::Manifest;
use crate::model::ParamStore;
use crate::rng::SplitMix64;
use crate::runtime::{ModelRuntime, Trainer};

pub fn run_hts(cfg: &RunConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    let info = manifest.model(&cfg.spec.model)?.clone();
    let b_cols = cfg.batch_columns();
    let alpha = cfg.alpha(info.unroll);
    anyhow::ensure!(
        alpha % info.unroll == 0,
        "sync interval {alpha} must be a multiple of unroll {}",
        info.unroll
    );

    // Learner-side runtime, initial parameters, trainer.
    let rt = ModelRuntime::new(manifest.clone())?;
    let init = rt.init_params(&cfg.spec.model, cfg.seed)?;
    let mut trainer =
        Trainer::new(&rt, &cfg.spec.model, cfg.algo, init.clone(), b_cols)?;

    // Shared system state.
    let dp = Arc::new(StripedSwap::new(alpha, b_cols, info.obs_dim,
                                       cfg.n_envs));
    let state_buf = Arc::new(StateBuffer::new());
    let act_buf = Arc::new(ActionBuffer::new(b_cols));
    let params = Arc::new(ParamStore::new(init.clone()));
    let sps = Arc::new(SpsMeter::new());
    let episodes: Arc<Mutex<Vec<EpisodePoint>>> =
        Arc::new(Mutex::new(Vec::new()));
    let signatures = Arc::new(AtomicU64::new(0));
    let watch = Stopwatch::new();

    // ---- executors -------------------------------------------------------
    let mut exec_handles = Vec::new();
    for e in 0..cfg.n_envs {
        let spec = cfg.spec.clone();
        let dp = dp.clone();
        let state_buf = state_buf.clone();
        let act_buf = act_buf.clone();
        let sps = sps.clone();
        let episodes = episodes.clone();
        let signatures = signatures.clone();
        let seed = cfg.seed;
        let n_agents = spec.n_agents;
        exec_handles.push(std::thread::spawn(move || -> Result<()> {
            let mut env_rng = SplitMix64::stream(seed, 1_000 + e as u64);
            let mut seed_rng = SplitMix64::stream(seed, 2_000 + e as u64);
            let mut delay_rng = SplitMix64::stream(seed, 3_000 + e as u64);
            let mut env = spec.build()?;
            let mut obs = env.reset(&mut env_rng);
            let mut ep_reward = 0.0f64;
            let mut sig = Fnv::default();
            sig.update(e as u64);
            let mut it = 0u64;
            let watch = Stopwatch::new();
            'outer: loop {
                // Claim this executor's private stripe for the whole
                // iteration: one CAS here, then every step below is a
                // plain unsynchronized write (the old code took a global
                // storage mutex on *every* step).
                let mut shard = dp.writer(e);
                for _t in 0..alpha {
                    // 1. publish observations with executor-drawn seeds
                    for a in 0..n_agents {
                        state_buf.push(ObsMsg {
                            slot: e * n_agents + a,
                            obs: obs[a].clone(),
                            seed: seed_rng.next_u64(),
                        });
                    }
                    // 2. await actions from whichever actor served us
                    let mut actions = Vec::with_capacity(n_agents);
                    for a in 0..n_agents {
                        match act_buf.take(e * n_agents + a) {
                            Some(act) => actions.push(act),
                            None => break 'outer, // shutdown
                        }
                    }
                    // 3. simulated engine latency + real env step
                    spec.steptime.sleep(&mut delay_rng);
                    let step = env.step(&actions, &mut env_rng);
                    // 4. record the transition (per agent column) —
                    // lock-free: the stripe is this thread's alone
                    for a in 0..n_agents {
                        shard.push(
                            e * n_agents + a,
                            &obs[a],
                            actions[a],
                            step.reward,
                            step.done,
                        );
                    }
                    let gsteps = sps.add(1);
                    for (a, &act) in actions.iter().enumerate() {
                        sig.update(((a as u64) << 32) | act as u64);
                    }
                    sig.update(step.reward.to_bits() as u64);
                    sig.update(step.done as u64);
                    ep_reward += step.reward as f64;
                    if step.done {
                        episodes.lock().unwrap().push(EpisodePoint {
                            steps: gsteps,
                            wall_s: watch.elapsed_s(),
                            reward: ep_reward,
                        });
                        ep_reward = 0.0;
                        obs = env.reset(&mut env_rng);
                    } else {
                        obs = step.obs;
                    }
                }
                // 5. bootstrap observations, then rendezvous (the writer
                // must be released before parking — the learner gathers
                // the stripes inside the publication window)
                for a in 0..n_agents {
                    shard.set_last_obs(e * n_agents + a, &obs[a]);
                }
                drop(shard);
                match dp.executor_arrive(it) {
                    Some(next) => it = next,
                    None => break,
                }
            }
            signatures.fetch_xor(sig.finish(), Ordering::Relaxed);
            Ok(())
        }));
    }

    // ---- actors ------------------------------------------------------------
    let actor_handles = spawn_actors(
        cfg.n_actors,
        cfg.spec.model.clone(),
        cfg.artifacts.clone(),
        state_buf.clone(),
        act_buf.clone(),
        params.clone(),
        b_cols,
    );

    // ---- evaluation worker -------------------------------------------------
    let eval = if cfg.eval_every > 0 {
        Some(EvalWorker::spawn(
            cfg.artifacts.clone(),
            cfg.spec.clone(),
            cfg.eval_episodes,
            cfg.seed ^ 0xe7a1,
        ))
    } else {
        None
    };

    // ---- learner (this thread) ----------------------------------------------
    // `gathered` is the learner-owned read storage: refilled zero-alloc
    // from the executor stripes at each swap barrier, then consumed
    // concurrently with the executors filling the next iteration.
    let mut gathered = RolloutStorage::new(alpha, b_cols, info.obs_dim);
    let mut behavior: Arc<Vec<f32>> = Arc::new(init);
    let mut it = 0u64;
    let mut last_out = Default::default();
    loop {
        if it >= 1 {
            // data collected in iteration it-1, gathered at the barrier
            last_out = trainer.step(&gathered, &behavior)?;
            if let Some(ev) = &eval {
                if trainer.updates % cfg.eval_every.max(1) == 0 {
                    ev.submit(
                        trainer.updates,
                        sps.steps(),
                        &watch,
                        Arc::new(trainer.params.clone()),
                    );
                }
            }
        }
        // Phase 1: wait for executors to park (all obs answered, no
        // in-flight inference).
        if !dp.learner_arrive(it) {
            break;
        }
        // Exclusive publication window: gather the stripes into the
        // [T, B] train view (fixed column order — deterministic),
        // remember the parameters that collected it (θ_{j-1}), then
        // publish θ_j for the executors' next iteration.
        dp.gather_and_reset(&mut gathered);
        behavior = params.latest().data.clone();
        params.publish(trainer.params.clone());
        if cfg.stop.done(sps.steps(), watch.elapsed_s(), trainer.updates) {
            dp.shutdown();
            state_buf.close();
            act_buf.close();
            break;
        }
        it = dp.learner_release(it);
    }

    for h in exec_handles {
        h.join().expect("executor panicked")?;
    }
    for h in actor_handles {
        h.join().expect("actor panicked")?;
    }

    let evals = match eval {
        Some(ev) => {
            // final snapshot for the last policy
            ev.submit(
                trainer.updates,
                sps.steps(),
                &watch,
                Arc::new(trainer.params.clone()),
            );
            ev.finish()?
        }
        None => Vec::new(),
    };

    let mut episodes = Arc::try_unwrap(episodes)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default();
    episodes.sort_by_key(|e| e.steps);

    Ok(TrainReport {
        method: "hts".into(),
        env: cfg.spec.name.clone(),
        seed: cfg.seed,
        steps: sps.steps(),
        updates: trainer.updates,
        wall_s: watch.elapsed_s(),
        episodes,
        evals,
        signature: signatures.load(Ordering::Relaxed),
        staleness: vec![1.0], // guaranteed lag of one (paper §4.1)
        final_loss: last_out.total_loss,
        final_entropy: last_out.entropy,
    })
}
