//! Layer-3 drivers — the paper's contribution and its baselines.
//!
//! * [`hts`] — **HTS-RL** (ours): executors/actors/learner with
//!   lock-free column-striped rollout shards gathered at the two-phase
//!   swap barrier (DESIGN.md §5), batch synchronization every α steps,
//!   one-step delayed gradient, deferred randomness (paper §4.1,
//!   Fig. 1e / Fig. 2d).
//! * [`sync_driver`] — the A2C/PPO baseline: per-step synchronization and
//!   strictly alternating rollout/learning (Fig. 1d / Fig. 2c).
//! * [`async_driver`] — the IMPALA/GA3C-style baseline: free-running
//!   executors feeding a non-blocking trajectory queue; the learner
//!   consumes stale data and corrects with V-trace. Policy lag is
//!   *measured* and reported (paper Claim 2 / Fig. 3c).

pub mod async_driver;
pub mod common;
pub mod hts;
pub mod sync_driver;

pub use common::{Method, RunConfig, StopCond};

use crate::metrics::TrainReport;
use crate::Result;

/// Dispatch a training run by method.
pub fn run(method: Method, cfg: &RunConfig) -> Result<TrainReport> {
    // Replica pooling is an HTS executor feature; silently ignoring it
    // for the baselines would let topology comparisons lie.
    anyhow::ensure!(
        method == Method::Hts || cfg.replicas_per_executor <= 1,
        "replicas_per_executor > 1 is only supported by the hts method"
    );
    match method {
        Method::Hts => hts::run_hts(cfg),
        Method::Sync => sync_driver::run_sync(cfg),
        Method::Async => async_driver::run_async(cfg),
    }
}
