//! The synchronous A2C/PPO baseline (paper Fig. 1d / Fig. 2c).
//!
//! Faithful to what the paper compares against (Kostrikov's A2C):
//!   * **per-step synchronization** (α = 1): at every timestep the driver
//!     batch-forwards all B observations, distributes actions, and waits
//!     for the *slowest* environment to finish its step before proceeding;
//!   * **strictly alternating** rollout and learning: after T steps the
//!     driver trains while all executors idle.
//!
//! Under step-time variance this pays `E[max_j X_j]` every step — the
//! quantity HTS-RL's batch synchronization amortizes (Claim 1) — so the
//! Fig. 4 speedups come out of exactly this structural difference.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::common::{EvalWorker, Fnv, RunConfig};
use crate::algo::sampling::sample_action;
use crate::buffers::{BlockingQueue, ColumnShard, RolloutStorage};
use crate::metrics::report::{EpisodePoint, SpsMeter, Stopwatch, TrainReport};
use crate::model::manifest::Manifest;
use crate::rng::SplitMix64;
use crate::runtime::{ForwardPool, ModelRuntime, Trainer};

/// Message to an executor: apply this action vector for this step. The
/// `out` plane is a recycled flat `[n_agents * obs_dim]` buffer the
/// executor writes the post-step observations into — the driver and each
/// executor pass the same two planes back and forth forever, so the
/// per-step protocol allocates nothing at steady state (DESIGN.md §7).
struct StepCmd {
    actions: Vec<usize>,
    out: Vec<f32>,
}

/// Executor reply: resulting flat observation plane (post-reset on done)
/// plus the applied actions (returned so the buffers recycle).
struct StepRes {
    env: usize,
    obs: Vec<f32>,
    actions: Vec<usize>,
    reward: f32,
    done: bool,
}

pub fn run_sync(cfg: &RunConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    let info = manifest.model(&cfg.spec.model)?.clone();
    let b_cols = cfg.batch_columns();
    let n_agents = cfg.spec.n_agents;
    let t_len = info.unroll;

    let rt = ModelRuntime::new(manifest.clone())?;
    let init = rt.init_params(&cfg.spec.model, cfg.seed)?;
    let mut trainer =
        Trainer::new(&rt, &cfg.spec.model, cfg.algo, init.clone(), b_cols)?;
    let pool = ForwardPool::new(&rt, &cfg.spec.model)?;

    let sps = Arc::new(SpsMeter::new());
    let stop_flag = Arc::new(AtomicBool::new(false));
    let results: Arc<BlockingQueue<StepRes>> = Arc::new(BlockingQueue::new());
    let watch = Stopwatch::new();

    // Per-env command mailboxes (the per-step barrier: the driver sends B
    // commands, then blocks until B results return).
    let cmds: Vec<Arc<BlockingQueue<StepCmd>>> =
        (0..cfg.n_envs).map(|_| Arc::new(BlockingQueue::new())).collect();

    let mut handles = Vec::new();
    for e in 0..cfg.n_envs {
        let spec = cfg.spec.clone();
        let cmd = cmds[e].clone();
        let results = results.clone();
        let seed = cfg.seed;
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut env_rng = SplitMix64::stream(seed, 1_000 + e as u64);
            let mut delay_rng = SplitMix64::stream(seed, 3_000 + e as u64);
            let mut env = spec.build()?;
            let width = env.n_agents() * env.obs_dim();
            let mut first = vec![0.0f32; width];
            env.reset_into(&mut env_rng, &mut first);
            results.push(StepRes {
                env: e,
                obs: first,
                actions: Vec::new(),
                reward: 0.0,
                done: false,
            });
            while let Some(mut c) = cmd.pop() {
                spec.steptime.sleep(&mut delay_rng);
                c.out.resize(width, 0.0);
                let info =
                    env.step_into(&c.actions, &mut env_rng, &mut c.out);
                if info.done {
                    // same stream position as before: reset draws after
                    // the step's draws
                    env.reset_into(&mut env_rng, &mut c.out);
                }
                results.push(StepRes {
                    env: e,
                    obs: c.out,
                    actions: c.actions,
                    reward: info.reward,
                    done: info.done,
                });
            }
            Ok(())
        }));
    }

    // collect initial observations (one flat plane per env)
    let mut cur_obs: Vec<Vec<f32>> = vec![Vec::new(); cfg.n_envs];
    for _ in 0..cfg.n_envs {
        let r = results.pop().expect("executor died");
        cur_obs[r.env] = r.obs;
    }
    // Recycled per-env scratch: the action vec and spare obs plane sent
    // with each command (refilled from every reply — no per-step allocs).
    let mut act_pool: Vec<Vec<usize>> =
        (0..cfg.n_envs).map(|_| Vec::with_capacity(n_agents)).collect();
    let mut out_pool: Vec<Vec<f32>> =
        (0..cfg.n_envs).map(|_| Vec::new()).collect();

    let eval = if cfg.eval_every > 0 {
        Some(EvalWorker::spawn(
            cfg.artifacts.clone(),
            cfg.spec.clone(),
            cfg.eval_episodes,
            cfg.seed ^ 0xe7a1,
        ))
    } else {
        None
    };

    let mut seed_rngs: Vec<SplitMix64> = (0..cfg.n_envs)
        .map(|e| SplitMix64::stream(cfg.seed, 2_000 + e as u64))
        .collect();
    // Rollouts are recorded through the same per-env column stripes the
    // HTS driver uses (one stripe per replica, gathered into the [T, B]
    // view before the learn phase) so both drivers share one layout
    // authority (DESIGN.md §5). The driver is single-threaded here, so
    // this is purely about API uniformity, not locking.
    let mut shards: Vec<ColumnShard> = (0..cfg.n_envs)
        .map(|e| {
            ColumnShard::new(t_len, e * n_agents, n_agents, info.obs_dim)
        })
        .collect();
    let mut storage = RolloutStorage::new(t_len, b_cols, info.obs_dim);
    let mut episodes: Vec<EpisodePoint> = Vec::new();
    let mut ep_rewards = vec![0.0f64; cfg.n_envs];
    let mut sig = Fnv::default();
    let mut last_out: crate::runtime::TrainOutput = Default::default();
    let _ = &last_out;

    // Hoisted step scratch: the batched forward input and the in-order
    // reply slots (reused every step — zero-alloc loop, DESIGN.md §7).
    let mut flat: Vec<f32> = Vec::with_capacity(b_cols * info.obs_dim);
    let mut replies: Vec<Option<StepRes>> =
        (0..cfg.n_envs).map(|_| None).collect();
    let d = info.obs_dim;

    'outer: loop {
        for sh in &mut shards {
            sh.clear();
        }
        for _t in 0..t_len {
            // one batched forward over all B columns
            flat.clear();
            for obs in &cur_obs {
                flat.extend_from_slice(obs);
            }
            let (logits, _v) =
                pool.forward(&trainer.params, &flat, b_cols)?;
            // distribute actions; every env steps; wait for ALL (α = 1)
            for e in 0..cfg.n_envs {
                let mut acts = std::mem::take(&mut act_pool[e]);
                acts.clear();
                acts.extend((0..n_agents).map(|a| {
                    let col = e * n_agents + a;
                    sample_action(
                        &logits[col * info.act_dim
                            ..(col + 1) * info.act_dim],
                        seed_rngs[e].next_u64(),
                    )
                }));
                let out = std::mem::take(&mut out_pool[e]);
                cmds[e].push(StepCmd { actions: acts, out });
            }
            // Barrier: collect all replies first, then process in env
            // order so telemetry (signature, episode log) is independent
            // of OS scheduling — the baseline must stay deterministic.
            for _ in 0..cfg.n_envs {
                let r = results.pop().expect("executor died");
                let env = r.env;
                replies[env] = Some(r);
            }
            for e in 0..cfg.n_envs {
                let r = replies[e].take().unwrap();
                for a in 0..n_agents {
                    shards[e].push(
                        e * n_agents + a,
                        &cur_obs[e][a * d..(a + 1) * d],
                        r.actions[a],
                        r.reward,
                        r.done,
                    );
                    sig.update(r.actions[a] as u64);
                }
                sig.update(r.reward.to_bits() as u64);
                let gsteps = sps.add(1);
                ep_rewards[e] += r.reward as f64;
                if r.done {
                    episodes.push(EpisodePoint {
                        steps: gsteps,
                        wall_s: watch.elapsed_s(),
                        reward: ep_rewards[e],
                    });
                    ep_rewards[e] = 0.0;
                }
                // recycle: the reply's buffers become the next command's
                act_pool[e] = r.actions;
                out_pool[e] = std::mem::replace(&mut cur_obs[e], r.obs);
            }
        }
        for e in 0..cfg.n_envs {
            for a in 0..n_agents {
                shards[e].set_last_obs(
                    e * n_agents + a,
                    &cur_obs[e][a * d..(a + 1) * d],
                );
            }
            storage.absorb(&shards[e]);
        }
        // alternating phase: learn while all executors idle.
        // On-policy: behavior == target (λ-lag 0); the a2c_delayed artifact
        // degrades to plain A2C in that case (python test asserts this).
        let behavior = trainer.params.clone();
        last_out = trainer.step(&storage, &behavior)?;
        if let Some(ev) = &eval {
            if trainer.updates % cfg.eval_every.max(1) == 0 {
                ev.submit(
                    trainer.updates,
                    sps.steps(),
                    &watch,
                    Arc::new(trainer.params.clone()),
                );
            }
        }
        if cfg.stop.done(sps.steps(), watch.elapsed_s(), trainer.updates) {
            break 'outer;
        }
    }

    stop_flag.store(true, Ordering::Relaxed);
    for c in &cmds {
        c.close();
    }
    results.close();
    for h in handles {
        h.join().expect("executor panicked")?;
    }
    let evals = match eval {
        Some(ev) => {
            ev.submit(
                trainer.updates,
                sps.steps(),
                &watch,
                Arc::new(trainer.params.clone()),
            );
            ev.finish()?
        }
        None => Vec::new(),
    };
    episodes.sort_by_key(|e| e.steps);

    Ok(TrainReport {
        method: "sync".into(),
        env: cfg.spec.name.clone(),
        seed: cfg.seed,
        steps: sps.steps(),
        updates: trainer.updates,
        wall_s: watch.elapsed_s(),
        episodes,
        evals,
        signature: sig.finish(),
        staleness: vec![0.0], // fully on-policy
        final_loss: last_out.total_loss,
        final_entropy: last_out.entropy,
        // The sync baseline steps envs on the learner thread with no
        // actor fleet or pools — nothing instrumented to report.
        telemetry: None,
        trace: None,
    })
}
