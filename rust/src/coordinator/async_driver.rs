//! The asynchronous IMPALA/GA3C-style baseline (paper Fig. 1b,c / Fig. 2b).
//!
//! Executors run free (no barrier): each collects a T-step trajectory,
//! stamps it with the parameter version in effect when it started, and
//! pushes it into a **non-blocking queue**. The learner drains the queue
//! into `[T, B]` batches and trains — by the time it does, the data is
//! stale: the measured per-trajectory policy lag (`learner version −
//! behavior version`) is reported in `TrainReport::staleness` and is the
//! empirical side of the paper's Claim 2 (`E[L] = nρ₀/(1−nρ₀)`).
//!
//! Off-policy correction is selected by `cfg.algo`: `Vtrace` reproduces
//! IMPALA; `A2cNoCorrection` reproduces uncorrected GA3C (Tab. A1).
//! Approximation note (DESIGN.md §9): the train artifact takes a single
//! behavior-parameter vector per batch, so ratios use the *oldest* version
//! in the batch; trajectories whose unroll spans a publish use their
//! start-of-unroll version.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::common::{spawn_actors, EvalWorker, Fnv, RunConfig};
use crate::buffers::{ActionBuffer, BlockingQueue, ColumnShard, ObsMsg,
                     RolloutStorage, StateBuffer};
use crate::metrics::report::{EpisodePoint, SpsMeter, Stopwatch, TrainReport};
use crate::model::manifest::Manifest;
use crate::model::ParamStore;
use crate::rng::SplitMix64;
use crate::runtime::{ModelRuntime, Trainer};

/// One executor-local trajectory (all agent columns of one env), laid
/// out on the flat observation plane (DESIGN.md §7): obs is
/// `[T, n_agents, D]` row-major, act is `[T, n_agents]`, last_obs is
/// `[n_agents, D]` — one allocation set per unroll, none per step.
struct Traj {
    /// producing env replica (diagnostics only since the learner
    /// assigns columns by batch slot)
    _env: usize,
    version: u64,
    obs: Vec<f32>,
    act: Vec<usize>,
    rew: Vec<f32>,
    done: Vec<f32>,
    last_obs: Vec<f32>,
}

pub fn run_async(cfg: &RunConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    let info = manifest.model(&cfg.spec.model)?.clone();
    let b_cols = cfg.batch_columns();
    let n_agents = cfg.spec.n_agents;
    let t_len = info.unroll;

    let rt = ModelRuntime::new(manifest.clone())?;
    let init = rt.init_params(&cfg.spec.model, cfg.seed)?;
    let mut trainer =
        Trainer::new(&rt, &cfg.spec.model, cfg.algo, init.clone(), b_cols)?;

    let state_buf = Arc::new(StateBuffer::with_telemetry(cfg.telemetry));
    let act_buf = Arc::new(ActionBuffer::new(b_cols));
    let params = Arc::new(ParamStore::with_history(init.clone(), 256));
    let traj_q: Arc<BlockingQueue<Traj>> = Arc::new(BlockingQueue::new());
    let sps = Arc::new(SpsMeter::new());
    let stop_flag = Arc::new(AtomicBool::new(false));
    let watch = Stopwatch::new();

    // ---- free-running executors -------------------------------------------
    // Episode logs and signatures are thread-local, merged at join (no
    // shared episode lock — DESIGN.md §6); the run's stopwatch is copied
    // in so episode timestamps share the eval/report origin.
    type ExecReport = (Vec<EpisodePoint>, u64);
    let mut exec_handles = Vec::new();
    for e in 0..cfg.n_envs {
        let spec = cfg.spec.clone();
        let state_buf = state_buf.clone();
        let act_buf = act_buf.clone();
        let traj_q = traj_q.clone();
        let params = params.clone();
        let sps = sps.clone();
        let stop_flag = stop_flag.clone();
        let seed = cfg.seed;
        exec_handles.push(std::thread::spawn(move || -> Result<ExecReport> {
            let mut env_rng = SplitMix64::stream(seed, 1_000 + e as u64);
            let mut seed_rng = SplitMix64::stream(seed, 2_000 + e as u64);
            let mut delay_rng = SplitMix64::stream(seed, 3_000 + e as u64);
            let mut env = spec.build()?;
            let d = env.obs_dim();
            let width = n_agents * d;
            // double-buffered flat planes: `obs` is the pending step's
            // input, `next` receives the post-step output
            let mut obs = vec![0.0f32; width];
            env.reset_into(&mut env_rng, &mut obs);
            let mut next = vec![0.0f32; width];
            let mut act_scratch: Vec<usize> = Vec::with_capacity(n_agents);
            // publish scratches: one free-list rent and one queue push
            // per step, regardless of agent count
            let mut buf_scratch: Vec<Vec<f32>> = Vec::with_capacity(n_agents);
            let mut msg_scratch: Vec<ObsMsg> = Vec::with_capacity(n_agents);
            let mut ep_reward = 0.0f64;
            let mut episodes: Vec<EpisodePoint> = Vec::new();
            let mut sig = Fnv::default();
            sig.update(e as u64);
            'outer: while !stop_flag.load(Ordering::Relaxed) {
                let version = params.version();
                let mut traj = Traj {
                    _env: e,
                    version,
                    obs: Vec::with_capacity(t_len * width),
                    act: Vec::with_capacity(t_len * n_agents),
                    rew: Vec::with_capacity(t_len),
                    done: Vec::with_capacity(t_len),
                    last_obs: Vec::new(),
                };
                for _t in 0..t_len {
                    state_buf.rent_into(&mut buf_scratch, n_agents, d);
                    for (a, mut buf) in buf_scratch.drain(..).enumerate() {
                        buf.extend_from_slice(&obs[a * d..(a + 1) * d]);
                        msg_scratch.push(ObsMsg::single(
                            e * n_agents + a,
                            buf,
                            seed_rng.next_u64(),
                        ));
                    }
                    let _ = state_buf.push_batch(&mut msg_scratch);
                    act_scratch.clear();
                    for a in 0..n_agents {
                        match act_buf.take(e * n_agents + a) {
                            Some(act) => act_scratch.push(act),
                            None => break 'outer,
                        }
                    }
                    spec.steptime.sleep(&mut delay_rng);
                    let info =
                        env.step_into(&act_scratch, &mut env_rng, &mut next);
                    traj.obs.extend_from_slice(&obs);
                    traj.act.extend_from_slice(&act_scratch);
                    traj.rew.push(info.reward);
                    traj.done.push(if info.done { 1.0 } else { 0.0 });
                    let gsteps = sps.add(1);
                    for &a in &act_scratch {
                        sig.update(a as u64);
                    }
                    sig.update(info.reward.to_bits() as u64);
                    ep_reward += info.reward as f64;
                    if info.done {
                        episodes.push(EpisodePoint {
                            steps: gsteps,
                            wall_s: watch.elapsed_s(),
                            reward: ep_reward,
                        });
                        ep_reward = 0.0;
                        env.reset_into(&mut env_rng, &mut next);
                    }
                    std::mem::swap(&mut obs, &mut next);
                }
                traj.last_obs.extend_from_slice(&obs);
                // non-blocking send: the queue is unbounded, exactly the
                // GA3C/IMPALA design whose length IS the policy lag.
                traj_q.push(traj);
            }
            Ok((episodes, sig.finish()))
        }));
    }

    // ---- actors -------------------------------------------------------------
    let actor_handles = spawn_actors(
        cfg.n_actors,
        cfg.spec.model.clone(),
        cfg.artifacts.clone(),
        state_buf.clone(),
        act_buf.clone(),
        params.clone(),
        b_cols,
        cfg.telemetry,
        // Async baseline is untraced: its executors are classic blocking
        // threads, and tracing exists to attribute *synchronous* stalls.
        None,
    );

    let eval = if cfg.eval_every > 0 {
        Some(EvalWorker::spawn(
            cfg.artifacts.clone(),
            cfg.spec.clone(),
            cfg.eval_episodes,
            cfg.seed ^ 0xe7a1,
        ))
    } else {
        None
    };

    // ---- learner (this thread) -----------------------------------------------
    // Batches are assembled through the shared column-stripe API: one
    // stripe per batch slot, gathered into the [T, B] view before the
    // train step (DESIGN.md §5). Single-threaded here — layout
    // uniformity with the HTS driver, not locking.
    let mut storage = RolloutStorage::new(t_len, b_cols, info.obs_dim);
    let n_traj = b_cols / n_agents;
    let mut slot_shards: Vec<ColumnShard> = (0..n_traj)
        .map(|slot| {
            ColumnShard::new(t_len, slot * n_agents, n_agents, info.obs_dim)
        })
        .collect();
    let mut staleness: Vec<f64> = Vec::new();
    let mut last_out = Default::default();
    'learn: loop {
        // Gather enough trajectories (in arrival order) to fill all B
        // columns. Trajectories are NOT necessarily from distinct envs —
        // a fast replica can contribute twice while a slow one lags, so
        // columns are assigned by batch slot, exactly like IMPALA's
        // learner batches.
        let mut batch: Vec<Traj> = Vec::with_capacity(n_traj);
        while batch.len() < n_traj {
            match traj_q.pop() {
                Some(t) => batch.push(t),
                None => break 'learn,
            }
        }
        let cur_version = params.version();
        let oldest = batch.iter().map(|t| t.version).min().unwrap();
        for t in &batch {
            staleness.push((cur_version - t.version) as f64);
        }
        let d = info.obs_dim;
        for (slot, traj) in batch.iter().enumerate() {
            let sh = &mut slot_shards[slot];
            sh.clear();
            for t in 0..t_len {
                for a in 0..n_agents {
                    let row = t * n_agents + a;
                    sh.push(
                        slot * n_agents + a,
                        &traj.obs[row * d..(row + 1) * d],
                        traj.act[row],
                        traj.rew[t],
                        traj.done[t] > 0.5,
                    );
                }
            }
            for a in 0..n_agents {
                sh.set_last_obs(
                    slot * n_agents + a,
                    &traj.last_obs[a * d..(a + 1) * d],
                );
            }
            storage.absorb(sh);
        }
        let behavior = params.get(oldest).data;
        last_out = trainer.step(&storage, &behavior)?;
        // async: publish immediately (no barrier) — the stale-policy source
        params.publish(trainer.params.clone());
        if let Some(ev) = &eval {
            if trainer.updates % cfg.eval_every.max(1) == 0 {
                ev.submit(
                    trainer.updates,
                    sps.steps(),
                    &watch,
                    Arc::new(trainer.params.clone()),
                );
            }
        }
        if cfg.stop.done(sps.steps(), watch.elapsed_s(), trainer.updates) {
            break;
        }
    }

    stop_flag.store(true, Ordering::Relaxed);
    state_buf.close();
    act_buf.close();
    traj_q.close();
    let mut episodes: Vec<EpisodePoint> = Vec::new();
    let mut signature = 0u64;
    for h in exec_handles {
        let (eps, sig) = h.join().expect("executor panicked")?;
        episodes.extend(eps);
        signature ^= sig;
    }
    let mut tel = crate::telemetry::TelemetryScope::new(false);
    for h in actor_handles {
        let scope = h.join().expect("actor panicked")?;
        tel.merge(&scope);
    }
    tel.merge(&state_buf.telemetry());
    let evals = match eval {
        Some(ev) => {
            ev.submit(
                trainer.updates,
                sps.steps(),
                &watch,
                Arc::new(trainer.params.clone()),
            );
            ev.finish()?
        }
        None => Vec::new(),
    };
    episodes.sort_by_key(|e| e.steps);

    Ok(TrainReport {
        method: "async".into(),
        env: cfg.spec.name.clone(),
        seed: cfg.seed,
        steps: sps.steps(),
        updates: trainer.updates,
        wall_s: watch.elapsed_s(),
        episodes,
        evals,
        signature,
        staleness,
        final_loss: last_out.total_loss,
        final_entropy: last_out.entropy,
        // Actor/buffer counters only: the async executors are classic
        // blocking threads, not instrumented pools.
        telemetry: cfg.telemetry.then(|| tel.report()),
        trace: None,
    })
}
