//! Per-file analysis: token-pattern rules, the nan-cmp window, hex-u64
//! wire discipline, unsafe/SAFETY coverage, delimiter balance, and the
//! `// lint:` directive grammar (allows + hotpath region markers).
//!
//! Execution order matters and is shared with the Python transliteration:
//! directives parse first (their errors are findings under the always-on
//! pseudo-rule `lint-directive`), then the token rules run, then allows
//! are applied — and any allow that suppressed nothing becomes a finding
//! itself, so annotations cannot rot silently.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Comment, Kind, Tok};
use super::manifest::{Manifest, Mode, KNOWN_RULES};

/// One diagnostic. `excerpt` is the trimmed source line, used both for
/// display and as the location-independent baseline key (line numbers
/// shift too easily to key on).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub message: String,
    pub excerpt: String,
}

/// One `unsafe` occurrence for the inventory. `safety` is the covering
/// `SAFETY:` excerpt; `None` means uncovered (also a finding).
#[derive(Debug, Clone)]
pub struct UnsafeSite {
    pub file: String,
    pub line: usize,
    pub safety: Option<String>,
}

/// `check_file` output for one source file.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub unsafe_sites: Vec<UnsafeSite>,
}

/// Token patterns per rule (each element matches one ident/punct token).
const PATTERNS: &[(&str, &[&[&str]])] = &[
    ("wall-clock", &[&["Instant", ":", ":", "now"], &["SystemTime"]]),
    ("thread-rng", &[&["thread_rng"], &["from_entropy"]]),
    ("map-iteration", &[&["HashMap"], &["HashSet"]]),
    ("hotpath-lock", &[&["Mutex"], &["RwLock"], &[".", "lock", "("]]),
    (
        "hotpath-alloc",
        &[
            &["format", "!"],
            &["vec", "!"],
            &["Vec", ":", ":", "new"],
            &["String", ":", ":", "new"],
            &["String", ":", ":", "from"],
            &["Box", ":", ":", "new"],
            &[".", "to_string", "("],
            &[".", "to_vec", "("],
        ],
    ),
];

/// Canonical one-line message per rule id.
pub fn message(rule: &str) -> &'static str {
    match rule {
        "wall-clock" => {
            "wall-clock read in a deterministic zone (telemetry/perf/deadline code is \
             zone-exempt; else justify with `// lint: allow(wall-clock, <why>)`)"
        }
        "thread-rng" => "non-deterministic RNG source (use seeded SplitMix64 streams)",
        "nan-cmp" => "partial_cmp().unwrap() is NaN-unsafe (use total_cmp)",
        "map-iteration" => {
            "hash-ordered container in artifact-producing code (use BTreeMap/BTreeSet, or \
             prove order-independence with `// lint: allow(map-iteration, <proof>)`)"
        }
        "hex-u64" => "raw u64 (de)serialization outside util::json (use hex_u64/parse_hex_u64)",
        "hotpath-lock" => {
            "lock primitive in a hot-path region (justify with \
             `// lint: allow(hotpath-lock, <why>)`)"
        }
        "hotpath-alloc" => {
            "allocation in a hot-path region (justify with \
             `// lint: allow(hotpath-alloc, <why>)`)"
        }
        "unsafe-safety" => "`unsafe` without a covering `// SAFETY:` comment",
        "delimiters" => "unbalanced delimiters",
        "cargo-offline" => {
            "non-path dependency breaks the offline-build guarantee (vendor it under \
             rust/vendor/)"
        }
        _ => "lint directive error",
    }
}

fn tok_match(t: &Tok, el: &str) -> bool {
    (t.kind == Kind::Ident || t.kind == Kind::Punct) && t.text == el
}

/// A parsed `// lint: allow(rule, reason)` annotation. `scope` holds the
/// line(s) it suppresses on: its own line plus, when the comment stands
/// alone, the next token-bearing line below it.
struct Allow {
    line: usize,
    rule: String,
    scope: Vec<usize>,
    used: bool,
}

/// Extract allows + hotpath regions from the comment stream; malformed
/// directives and marker mismatches are returned as (line, message)
/// errors that the caller files under `lint-directive`.
#[allow(clippy::type_complexity)]
fn parse_directives(
    comments: &[Comment],
    token_lines: &BTreeSet<usize>,
) -> (Vec<Allow>, Vec<(usize, usize)>, Vec<(usize, String)>) {
    let mut allows = Vec::new();
    let mut regions = Vec::new();
    let mut errors = Vec::new();
    let mut open_begin: Option<usize> = None;
    for c in comments {
        let body = c
            .text
            .trim_start_matches('/')
            .trim_start_matches('!')
            .trim_start_matches('*')
            .trim();
        let Some(d) = body.strip_prefix("lint:") else {
            continue;
        };
        let d = d.trim();
        if let Some(inner) = d.strip_prefix("allow(").and_then(|x| x.strip_suffix(')')) {
            let (rule, reason) = match inner.find(',') {
                Some(p) => (inner[..p].trim(), inner[p + 1..].trim()),
                None => (inner.trim(), ""),
            };
            if !KNOWN_RULES.contains(&rule) {
                errors.push((c.line, format!("allow names unknown rule '{rule}'")));
                continue;
            }
            if reason.is_empty() {
                errors.push((c.line, "allow needs a reason: lint: allow(rule, why)".into()));
                continue;
            }
            let mut scope = vec![c.line];
            if !token_lines.contains(&c.line) {
                if let Some(&nxt) = token_lines.range(c.end_line + 1..).next() {
                    scope.push(nxt);
                }
            }
            allows.push(Allow {
                line: c.line,
                rule: rule.to_string(),
                scope,
                used: false,
            });
        } else if d.starts_with("hotpath(begin") && d.ends_with(')') {
            if let Some(prev) = open_begin {
                errors.push((
                    c.line,
                    format!("nested hotpath(begin) — close the previous region opened at line {prev}"),
                ));
                continue;
            }
            open_begin = Some(c.line);
        } else if d == "hotpath(end)" {
            match open_begin.take() {
                Some(b) => regions.push((b, c.line)),
                None => errors.push((c.line, "hotpath(end) without a matching begin".into())),
            }
        } else {
            errors.push((c.line, format!("unparseable lint directive: '{d}'")));
        }
    }
    if let Some(b) = open_begin {
        errors.push((b, "hotpath(begin) never closed".into()));
    }
    (allows, regions, errors)
}

fn push_finding(
    findings: &mut Vec<Finding>,
    rel: &str,
    lines: &[&str],
    line: usize,
    rule: &str,
    msg: String,
) {
    let excerpt = lines.get(line - 1).map(|s| s.trim().to_string()).unwrap_or_default();
    findings.push(Finding {
        file: rel.to_string(),
        line,
        rule: rule.to_string(),
        message: msg,
        excerpt,
    });
}

/// Run every source-file rule over one file.
pub fn check_file(rel: &str, src: &str, manifest: &Manifest) -> FileReport {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let lines: Vec<&str> = src.lines().collect();
    let token_lines: BTreeSet<usize> = toks.iter().map(|t| t.line).collect();

    let mut findings: Vec<Finding> = Vec::new();
    let (mut allows, regions, errors) = parse_directives(&lexed.comments, &token_lines);
    for (line, msg) in errors {
        push_finding(&mut findings, rel, &lines, line, "lint-directive", msg);
    }
    let in_region = |line: usize| regions.iter().any(|&(b, e)| (b..=e).contains(&line));

    // -- simple token-pattern rules (dedup by rule + line) ---------------
    let mut seen: BTreeSet<(String, usize)> = BTreeSet::new();
    let mut emit = |findings: &mut Vec<Finding>, line: usize, rule: &str, msg: String| {
        if seen.insert((rule.to_string(), line)) {
            push_finding(findings, rel, &lines, line, rule, msg);
        }
    };

    for (rule, pats) in PATTERNS {
        let hot = matches!(manifest.bindings.get(*rule), Some(Mode::Hotpath));
        if !hot && !manifest.active(rule, rel) {
            continue;
        }
        for pat in *pats {
            for w in toks.windows(pat.len()) {
                if w.iter().zip(pat.iter()).all(|(t, el)| tok_match(t, el)) {
                    let line = w[0].line;
                    if hot && !in_region(line) {
                        continue;
                    }
                    emit(&mut findings, line, rule, message(rule).to_string());
                }
            }
        }
    }

    // -- nan-cmp: partial_cmp followed by unwrap within 8 tokens ---------
    if manifest.active("nan-cmp", rel) {
        for (i, t) in toks.iter().enumerate() {
            if t.kind == Kind::Ident && t.text == "partial_cmp" {
                let tail = &toks[i + 1..(i + 9).min(toks.len())];
                if tail.iter().any(|u| u.kind == Kind::Ident && u.text == "unwrap") {
                    emit(&mut findings, t.line, "nan-cmp", message("nan-cmp").to_string());
                }
            }
        }
    }

    // -- hex-u64: hex format specs / radix parsing in the zone -----------
    if manifest.active("hex-u64", rel) {
        for t in toks {
            let hit = (t.kind == Kind::Str && t.text.contains("016x"))
                || (t.kind == Kind::Ident && t.text == "from_str_radix");
            if hit {
                emit(&mut findings, t.line, "hex-u64", message("hex-u64").to_string());
            }
        }
    }

    // -- unsafe-safety + inventory ---------------------------------------
    let mut unsafe_sites = Vec::new();
    if manifest.active("unsafe-safety", rel) {
        // Lines covered only by comments (no tokens): the lookup table
        // for "contiguous comment block immediately above".
        let mut comment_only: BTreeMap<usize, Vec<&str>> = BTreeMap::new();
        for c in &lexed.comments {
            for l in c.line..=c.end_line {
                comment_only.entry(l).or_default().push(c.text.as_str());
            }
        }
        for l in &token_lines {
            comment_only.remove(l);
        }

        let covering_comment = |line: usize| -> Option<String> {
            // Trailing comment on the same line.
            for c in &lexed.comments {
                if (c.line..=c.end_line).contains(&line) && c.text.contains("SAFETY:") {
                    return Some(c.text.clone());
                }
            }
            // Contiguous comment-only block immediately above.
            let mut l = line - 1;
            let mut block: Vec<&str> = Vec::new();
            while let Some(texts) = comment_only.get(&l) {
                block.extend(texts.iter().copied());
                if l == 0 {
                    break;
                }
                l -= 1;
            }
            block
                .iter()
                .find(|t| t.contains("SAFETY:"))
                .map(|t| (*t).to_string())
        };

        let mut depth = 0usize;
        // Brace depths whose enclosing `unsafe` item carried a SAFETY
        // comment: nested `unsafe` inside (e.g. calls in an `unsafe impl`
        // method) inherit that coverage.
        let mut covered_stack: Vec<usize> = Vec::new();
        let mut pending_cover = false;
        for t in toks {
            if t.kind == Kind::Punct && matches!(t.text.as_str(), "(" | "[" | "{") {
                depth += 1;
                if t.text == "{" && pending_cover {
                    covered_stack.push(depth);
                    pending_cover = false;
                }
            } else if t.kind == Kind::Punct && matches!(t.text.as_str(), ")" | "]" | "}") {
                if t.text == "}" && covered_stack.last() == Some(&depth) {
                    covered_stack.pop();
                }
                depth = depth.saturating_sub(1);
            } else if t.kind == Kind::Punct && t.text == ";" {
                pending_cover = false;
            } else if t.kind == Kind::Ident && t.text == "unsafe" {
                if !covered_stack.is_empty() {
                    unsafe_sites.push(UnsafeSite {
                        file: rel.to_string(),
                        line: t.line,
                        safety: Some(
                            "(covered by enclosing unsafe item's SAFETY comment)".to_string(),
                        ),
                    });
                    pending_cover = true;
                    continue;
                }
                match covering_comment(t.line) {
                    None => {
                        emit(
                            &mut findings,
                            t.line,
                            "unsafe-safety",
                            message("unsafe-safety").to_string(),
                        );
                        unsafe_sites.push(UnsafeSite {
                            file: rel.to_string(),
                            line: t.line,
                            safety: None,
                        });
                    }
                    Some(text) => {
                        let flat = text.split_whitespace().collect::<Vec<_>>().join(" ");
                        let idx = flat.find("SAFETY:").unwrap_or(0);
                        let excerpt: String = flat[idx..].chars().take(120).collect();
                        unsafe_sites.push(UnsafeSite {
                            file: rel.to_string(),
                            line: t.line,
                            safety: Some(excerpt),
                        });
                        pending_cover = true;
                    }
                }
            }
        }
    }

    // -- delimiters ------------------------------------------------------
    if manifest.active("delimiters", rel) {
        let mut stack: Vec<(char, usize)> = Vec::new();
        let mut bad: Option<(usize, String)> = None;
        for t in toks {
            if t.kind != Kind::Punct {
                continue;
            }
            let ch = t.text.chars().next().unwrap_or(' ');
            match ch {
                '(' | '[' | '{' => stack.push((ch, t.line)),
                ')' | ']' | '}' => {
                    let want = match ch {
                        ')' => '(',
                        ']' => '[',
                        _ => '{',
                    };
                    if stack.last().map(|&(c, _)| c) != Some(want) {
                        bad = Some((t.line, format!("unmatched '{ch}'")));
                        break;
                    }
                    stack.pop();
                }
                _ => {}
            }
        }
        if let Some((line, why)) = bad {
            let msg = format!("{}: {}", message("delimiters"), why);
            emit(&mut findings, line, "delimiters", msg);
        } else if let Some(&(ch, line)) = stack.last() {
            let msg = format!("{}: '{}' never closed", message("delimiters"), ch);
            emit(&mut findings, line, "delimiters", msg);
        }
    }

    // -- apply allows; unused allows are findings themselves -------------
    let mut kept = Vec::new();
    for f in findings {
        let mut suppressed = false;
        for a in allows.iter_mut() {
            if a.rule == f.rule && a.scope.contains(&f.line) {
                a.used = true;
                suppressed = true;
                break;
            }
        }
        if !suppressed {
            kept.push(f);
        }
    }
    for a in &allows {
        if !a.used {
            let msg = format!(
                "unused lint: allow({}, ...) — the rule no longer fires here; drop the annotation",
                a.rule
            );
            push_finding(&mut kept, rel, &lines, a.line, "lint-directive", msg);
        }
    }
    FileReport {
        findings: kept,
        unsafe_sites,
    }
}

/// The cargo-offline rule: every `[dependencies]`-section entry must be
/// an inline table with a `path` key and no `git`/`version`/`registry`
/// escape hatches (the container build has no network; DESIGN.md §3).
pub fn check_cargo(origin: &str, text: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut section = String::new();
    for (ln0, raw) in text.lines().enumerate() {
        let ln = ln0 + 1;
        let s = raw.trim();
        if s.starts_with('[') {
            section = s.trim_matches(|c| c == '[' || c == ']').to_string();
            continue;
        }
        if !section.ends_with("dependencies") || s.is_empty() || s.starts_with('#') {
            continue;
        }
        let Some((name, val)) = s.split_once('=') else {
            continue;
        };
        let val = val.trim();
        let bad = if val.starts_with('{') {
            let has_path = val
                .trim_matches(|c| c == '{' || c == '}')
                .split(',')
                .any(|kv| kv.split('=').next().map(str::trim) == Some("path"));
            let hazard = ["git =", "git=", "version =", "version=", "registry"]
                .iter()
                .any(|w| val.contains(w));
            !has_path || hazard
        } else {
            true // bare `name = "1.0"` — a crates.io version requirement
        };
        if bad {
            findings.push(Finding {
                file: origin.to_string(),
                line: ln,
                rule: "cargo-offline".to_string(),
                message: format!("{} (dep '{}')", message("cargo-offline"), name.trim()),
                excerpt: s.to_string(),
            });
        }
    }
    findings
}
