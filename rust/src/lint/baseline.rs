//! Baseline bookkeeping: known findings, keyed location-independently.
//!
//! A baseline entry is `(rule, file, excerpt)` — the trimmed source line,
//! not the line number, so unrelated edits above a baselined site don't
//! invalidate it. Checking consumes entries count-wise: findings beyond
//! an entry's count are fresh (fail), and entries no finding consumed are
//! stale (also fail under `--ci`, so the baseline can only shrink —
//! the same ratchet discipline as `BENCH_baseline.json`, DESIGN.md §12).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::rules::Finding;
use crate::util::json::{obj, Json};

/// Baseline key: (rule, file, trimmed source line).
pub type Key = (String, String, String);

fn key_of(f: &Finding) -> Key {
    (f.rule.clone(), f.file.clone(), f.excerpt.clone())
}

/// Parse `lint_baseline.json` (`{"v":1,"entries":[{rule,file,excerpt,count}]}`).
pub fn parse(text: &str) -> Result<BTreeMap<Key, u64>> {
    let v = Json::parse(text).context("parsing lint baseline JSON")?;
    let mut out: BTreeMap<Key, u64> = BTreeMap::new();
    for e in v.get("entries")?.as_arr()? {
        let k = (
            e.get("rule")?.as_str()?.to_string(),
            e.get("file")?.as_str()?.to_string(),
            e.get("excerpt")?.as_str()?.to_string(),
        );
        let n = match e.opt("count") {
            Some(c) => c.as_u64()?,
            None => 1,
        };
        *out.entry(k).or_insert(0) += n;
    }
    Ok(out)
}

/// Serialize findings as a baseline document (used by `--update-baseline`).
pub fn render(findings: &[Finding]) -> String {
    let mut counts: BTreeMap<Key, u64> = BTreeMap::new();
    for f in findings {
        *counts.entry(key_of(f)).or_insert(0) += 1;
    }
    let entries: Vec<Json> = counts
        .into_iter()
        .map(|((rule, file, excerpt), count)| {
            obj(vec![
                ("rule", Json::Str(rule)),
                ("file", Json::Str(file)),
                ("excerpt", Json::Str(excerpt)),
                ("count", Json::Num(count as f64)),
            ])
        })
        .collect();
    let top = obj(vec![("v", Json::Num(1.0)), ("entries", Json::Arr(entries))]);
    let mut s = top.to_string();
    s.push('\n');
    s
}

/// The result of subtracting a baseline from a finding list.
#[derive(Debug)]
pub struct Diff {
    /// Findings not covered by the baseline (these fail the run).
    pub fresh: Vec<Finding>,
    /// How many findings the baseline absorbed.
    pub baselined: usize,
    /// Baseline entries (with residual counts) nothing consumed.
    pub stale: Vec<(Key, u64)>,
}

pub fn apply(findings: Vec<Finding>, baseline: &BTreeMap<Key, u64>) -> Diff {
    let mut remaining = baseline.clone();
    let mut fresh = Vec::new();
    let mut baselined = 0usize;
    for f in findings {
        match remaining.get_mut(&key_of(&f)) {
            Some(n) if *n > 0 => {
                *n -= 1;
                baselined += 1;
            }
            _ => fresh.push(f),
        }
    }
    let stale = remaining.into_iter().filter(|(_, n)| *n > 0).collect();
    Diff {
        fresh,
        baselined,
        stale,
    }
}
