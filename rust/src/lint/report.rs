//! Rendering: `file:line: [rule] message` diagnostics for humans, plus a
//! machine-readable JSON document (findings + the unsafe inventory) for
//! CI artifact upload and downstream tooling.

use crate::util::json::{obj, Json};

use super::LintOutcome;

/// Human-readable diagnostics + one summary line.
pub fn text(out: &LintOutcome) -> String {
    let mut s = String::new();
    for f in &out.findings {
        s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    for ((rule, file, excerpt), n) in &out.stale {
        s.push_str(&format!(
            "note: stale baseline entry [{rule}] {file} '{excerpt}' x{n}\n"
        ));
    }
    s.push_str(&format!(
        "hts-lint: {} files, {} finding(s), {} baselined, {} unsafe site(s)\n",
        out.files,
        out.findings.len(),
        out.baselined,
        out.unsafe_sites.len()
    ));
    s
}

/// Machine-readable document: `{v, files, findings[], baselined,
/// unsafe_inventory[]}` (uncovered sites carry `"safety": "UNCOVERED"`).
pub fn json(out: &LintOutcome) -> Json {
    let findings: Vec<Json> = out
        .findings
        .iter()
        .map(|f| {
            obj(vec![
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("rule", Json::Str(f.rule.clone())),
                ("message", Json::Str(f.message.clone())),
                ("excerpt", Json::Str(f.excerpt.clone())),
            ])
        })
        .collect();
    let inventory: Vec<Json> = out
        .unsafe_sites
        .iter()
        .map(|u| {
            let safety = u.safety.clone().unwrap_or_else(|| "UNCOVERED".to_string());
            obj(vec![
                ("file", Json::Str(u.file.clone())),
                ("line", Json::Num(u.line as f64)),
                ("safety", Json::Str(safety)),
            ])
        })
        .collect();
    obj(vec![
        ("v", Json::Num(1.0)),
        ("files", Json::Num(out.files as f64)),
        ("findings", Json::Arr(findings)),
        ("baselined", Json::Num(out.baselined as f64)),
        ("unsafe_inventory", Json::Arr(inventory)),
    ])
}
