//! `hts-lint`: a determinism & concurrency static-analysis pass that
//! machine-checks this repo's bit-exactness invariants (DESIGN.md §14).
//!
//! The codebase promises byte-identical artifacts for a fixed seed
//! across thread counts, lane widths, and host fleets. Most of the ways
//! to silently break that promise are *textual*: an `Instant::now()`
//! that leaks into control flow, a `HashMap` iterated while writing a
//! report, a `partial_cmp().unwrap()` that panics on the first NaN, a
//! `format!("{:x}")` that bypasses the canonical hex-u64 wire helpers.
//! This module lexes the whole source tree with a comment/string-aware
//! tokenizer ([`lexer`]) and enforces zoned rules from a committed
//! manifest (`rust/lint.rules`, parsed fail-closed by [`manifest`]):
//!
//! * `wall-clock` — real-time reads outside the timekeeping zone
//! * `thread-rng` — OS-entropy RNG anywhere
//! * `nan-cmp` — `partial_cmp().unwrap()` anywhere
//! * `map-iteration` — hash-ordered containers in artifact-producing code
//! * `hex-u64` — raw u64 wire formatting outside `util::json`
//! * `hotpath-lock` / `hotpath-alloc` — lock/alloc discipline inside
//!   `// lint: hotpath(begin, …)` marker regions
//! * `unsafe-safety` — every `unsafe` needs a covering `SAFETY:` comment
//!   (all sites are exported as an inventory either way)
//! * `delimiters` — the promoted PR 6 balance scanner
//! * `cargo-offline` — `Cargo.toml` deps must be vendored path crates
//!
//! Violations a human has justified carry
//! `// lint: allow(<rule>, <reason>)`; an allow that stops suppressing
//! anything becomes a finding itself. Legacy findings live in a counted
//! baseline (`rust/lint_baseline.json`, empty today) that can only
//! shrink. The `hts-lint` binary (`src/bin/hts_lint.rs`) drives this
//! from CI, fail-closed; `python/tools/hts_lint.py` is a transliteration
//! for toolchain-free environments and must agree finding-for-finding
//! (asserted over the fixture corpus by `rust/tests/lint.rs`).

pub mod baseline;
pub mod lexer;
pub mod manifest;
pub mod report;
pub mod rules;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use manifest::Manifest;
use rules::{Finding, UnsafeSite};

/// Inputs for one lint run.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Source tree root (usually `rust/src`).
    pub root: PathBuf,
    /// Rule manifest path (usually `rust/lint.rules`).
    pub manifest: PathBuf,
    /// Baseline path; `None` (or a missing file) means empty baseline.
    pub baseline: Option<PathBuf>,
    /// `Cargo.toml` for the cargo-offline rule; `None` skips it.
    pub cargo: Option<PathBuf>,
}

/// One full run over the tree.
#[derive(Debug)]
pub struct LintOutcome {
    /// How many `.rs` files were scanned.
    pub files: usize,
    /// Fresh (unbaselined) findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// How many findings the baseline absorbed.
    pub baselined: usize,
    /// Stale baseline entries with residual counts.
    pub stale: Vec<(baseline::Key, u64)>,
    /// Every `unsafe` site, covered or not, in scan order.
    pub unsafe_sites: Vec<UnsafeSite>,
}

/// All `.rs` files under `root`, in sorted-walk order (deterministic
/// across hosts; the final finding order is a sort anyway).
pub fn rs_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    walk(root, &mut out)?;
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension() == Some(std::ffi::OsStr::new("rs")) {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the tree: lex + check every file, run the cargo rule, subtract
/// the baseline. Fails only on I/O or an invalid manifest/baseline —
/// findings are data, the caller decides the exit code.
pub fn run(cfg: &LintConfig) -> Result<LintOutcome> {
    let mtext = fs::read_to_string(&cfg.manifest)
        .with_context(|| format!("reading manifest {}", cfg.manifest.display()))?;
    let manifest = Manifest::parse(&mtext, &cfg.manifest.display().to_string())?;
    let files = rs_files(&cfg.root)?;
    let mut findings: Vec<Finding> = Vec::new();
    let mut unsafe_sites: Vec<UnsafeSite> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&cfg.root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src =
            fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let rep = rules::check_file(&rel, &src, &manifest);
        findings.extend(rep.findings);
        unsafe_sites.extend(rep.unsafe_sites);
    }
    if let Some(cp) = &cfg.cargo {
        if cp.exists() {
            let text =
                fs::read_to_string(cp).with_context(|| format!("reading {}", cp.display()))?;
            findings.extend(rules::check_cargo(&cp.display().to_string(), &text));
        }
    }
    findings.sort();
    let base: BTreeMap<baseline::Key, u64> = match &cfg.baseline {
        Some(bp) if bp.exists() => {
            let text = fs::read_to_string(bp)
                .with_context(|| format!("reading baseline {}", bp.display()))?;
            baseline::parse(&text).with_context(|| format!("in {}", bp.display()))?
        }
        _ => BTreeMap::new(),
    };
    let diff = baseline::apply(findings, &base);
    Ok(LintOutcome {
        files: files.len(),
        findings: diff.fresh,
        baselined: diff.baselined,
        stale: diff.stale,
        unsafe_sites,
    })
}
