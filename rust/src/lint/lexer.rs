//! Comment/string/raw-string/char-literal/lifetime-aware tokenizer.
//!
//! Promoted from the PR 6 delimiter scanner: instead of merely skipping
//! quoted regions, this lexer produces a token stream (identifiers,
//! punctuation, string contents, char literals, numbers, lifetimes) plus
//! the comment list, so rules can match API usage without firing on
//! prose, string literals, or commented-out code.
//!
//! Mirrored line-for-line by the Python transliteration in
//! `python/tools/hts_lint.py` (`lex` / `_string` / `_quote`); the two
//! must stay branch-identical so both sides agree finding-for-finding.
//!
//! Deliberate limits (shared with the transliteration): the lexer never
//! fails — unterminated strings/comments consume to EOF and the
//! `delimiters` rule reports the imbalance; raw *identifiers* (`r#type`)
//! are not recognized (none exist in this tree; introducing one would
//! surface as a delimiter imbalance, not silence).

/// Token classification. `Str` carries the literal's *content* (quotes
/// excluded) so content rules (e.g. the `016x` probe) can search it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Str,
    Char,
    Num,
    Lifetime,
}

/// One token, tagged with the 1-based line it starts on.
#[derive(Debug, Clone)]
pub struct Tok {
    pub line: usize,
    pub kind: Kind,
    pub text: String,
}

/// One comment (line `//…` or block `/*…*/`, nesting included), spanning
/// `line..=end_line`, raw text preserved (directive parsing strips the
/// leading punctuation itself).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub end_line: usize,
    pub text: String,
}

/// Lexer output: the token stream and the comment list.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Escaped-string prefixes (`b"…"`, `c"…"`).
fn is_string_prefix(name: &str) -> bool {
    name == "b" || name == "c"
}

/// Raw-string prefixes (`r"…"`, `br#"…"#`, `cr"…"`).
fn is_raw_prefix(name: &str) -> bool {
    name == "r" || name == "br" || name == "cr"
}

struct Lexer {
    c: Vec<char>,
    i: usize,
    line: usize,
    out: Lexed,
}

/// Tokenize `src`. Never fails on malformed input.
pub fn lex(src: &str) -> Lexed {
    let mut lx = Lexer {
        c: src.chars().collect(),
        i: 0,
        line: 1,
        out: Lexed::default(),
    };
    lx.run();
    lx.out
}

impl Lexer {
    /// Char at `i`, or NUL past the end (never a token char).
    fn at(&self, i: usize) -> char {
        self.c.get(i).copied().unwrap_or('\0')
    }

    fn slice(&self, a: usize, b: usize) -> String {
        self.c[a..b.min(self.c.len())].iter().collect()
    }

    fn push(&mut self, line: usize, kind: Kind, text: String) {
        self.out.toks.push(Tok { line, kind, text });
    }

    fn run(&mut self) {
        while self.i < self.c.len() {
            let ch = self.c[self.i];
            if ch == '\n' {
                self.line += 1;
                self.i += 1;
            } else if ch == ' ' || ch == '\t' || ch == '\r' {
                self.i += 1;
            } else if ch == '/' && self.at(self.i + 1) == '/' {
                self.line_comment();
            } else if ch == '/' && self.at(self.i + 1) == '*' {
                self.block_comment();
            } else if ch == '"' {
                self.string(false);
            } else if ch == '\'' {
                self.quote();
            } else if is_ident_start(ch) {
                self.ident();
            } else if ch.is_ascii_digit() {
                self.number();
            } else {
                let line = self.line;
                self.push(line, Kind::Punct, ch.to_string());
                self.i += 1;
            }
        }
    }

    fn line_comment(&mut self) {
        let mut j = self.i;
        while j < self.c.len() && self.c[j] != '\n' {
            j += 1;
        }
        let text = self.slice(self.i, j);
        self.out.comments.push(Comment {
            line: self.line,
            end_line: self.line,
            text,
        });
        self.i = j;
    }

    fn block_comment(&mut self) {
        let start_line = self.line;
        let mut depth = 1usize;
        let mut j = self.i + 2;
        while j < self.c.len() && depth > 0 {
            if self.c[j] == '\n' {
                self.line += 1;
                j += 1;
            } else if self.c[j] == '/' && self.at(j + 1) == '*' {
                depth += 1;
                j += 2;
            } else if self.c[j] == '*' && self.at(j + 1) == '/' {
                depth -= 1;
                j += 2;
            } else {
                j += 1;
            }
        }
        let text = self.slice(self.i, j);
        self.out.comments.push(Comment {
            line: start_line,
            end_line: self.line,
            text,
        });
        self.i = j;
    }

    /// Lex a string with `self.i` at the opening `"` (or at the `#` run
    /// of a raw string). Content excludes the quotes.
    fn string(&mut self, raw: bool) {
        let start_line = self.line;
        let mut hashes = 0usize;
        while self.at(self.i) == '#' {
            hashes += 1;
            self.i += 1;
        }
        self.i += 1; // opening quote
        let content_start = self.i;
        while self.i < self.c.len() {
            let ch = self.c[self.i];
            if ch == '\n' {
                self.line += 1;
                self.i += 1;
            } else if !raw && ch == '\\' {
                self.i += 2;
            } else if ch == '"' {
                if raw && hashes > 0 {
                    if (1..=hashes).all(|k| self.at(self.i + k) == '#') {
                        let text = self.slice(content_start, self.i);
                        self.push(start_line, Kind::Str, text);
                        self.i += 1 + hashes;
                        return;
                    }
                    self.i += 1;
                } else {
                    let text = self.slice(content_start, self.i);
                    self.push(start_line, Kind::Str, text);
                    self.i += 1;
                    return;
                }
            } else {
                self.i += 1;
            }
        }
        // Unterminated: consume to EOF (the delimiters rule reports).
        let text = self.slice(content_start, self.c.len());
        self.push(start_line, Kind::Str, text);
    }

    /// Disambiguate char literal vs lifetime with `self.i` at `'`.
    fn quote(&mut self) {
        let n = self.c.len();
        let i = self.i;
        let j = i + 1;
        if self.at(j) == '\\' {
            // Escaped char literal: the backslash + escaped char are
            // consumed blindly (covers `'\''` and `'\\'`), then scan to
            // the closing quote.
            let mut k = j + 2;
            while k < n && self.c[k] != '\'' {
                k += 1;
            }
            let text = self.slice(i, k + 1);
            let line = self.line;
            self.push(line, Kind::Char, text);
            self.i = (k + 1).min(n);
        } else if j < n && is_ident_cont(self.c[j]) && self.at(j + 1) != '\'' {
            // Lifetime: 'a, 'static, '_ — an ident char NOT followed by
            // a closing quote.
            let mut k = j;
            while k < n && is_ident_cont(self.c[k]) {
                k += 1;
            }
            let text = self.slice(i, k);
            let line = self.line;
            self.push(line, Kind::Lifetime, text);
            self.i = k;
        } else {
            // Plain char literal 'x' (including '"' and '\n').
            let mut k = j;
            while k < n && self.c[k] != '\'' {
                k += 1;
            }
            if k >= n {
                k = n.saturating_sub(1);
            }
            let text = self.slice(i, k + 1);
            let nl = text.chars().filter(|&c| c == '\n').count();
            let line = self.line;
            self.push(line, Kind::Char, text);
            self.line += nl;
            self.i = k + 1;
        }
    }

    fn ident(&mut self) {
        let n = self.c.len();
        let mut j = self.i + 1;
        while j < n && is_ident_cont(self.c[j]) {
            j += 1;
        }
        let name = self.slice(self.i, j);
        let nj = self.at(j);
        if nj == '"' && is_string_prefix(&name) {
            self.i = j;
            self.string(false);
        } else if nj == '"' && is_raw_prefix(&name) {
            self.i = j;
            self.string(true);
        } else if nj == '#' && is_raw_prefix(&name) {
            self.i = j;
            self.string(true);
        } else if nj == '\'' && name == "b" {
            self.i = j;
            self.quote();
        } else {
            let line = self.line;
            self.push(line, Kind::Ident, name);
            self.i = j;
        }
    }

    fn number(&mut self) {
        let n = self.c.len();
        let start = self.i;
        let mut j = self.i + 1;
        while j < n
            && (is_ident_cont(self.c[j])
                || (self.c[j] == '.' && j + 1 < n && self.c[j + 1].is_ascii_digit()))
        {
            j += 1;
        }
        // Exponent sign: 1.5e-3 / 2E+8. When the sign test is reached,
        // `j - 1 > start` holds (the `e` was consumed above), so `j >= 2`
        // and the `j - 2` lookback cannot underflow.
        while j < n
            && (self.c[j] == '+' || self.c[j] == '-')
            && (self.c[j - 1] == 'e' || self.c[j - 1] == 'E')
            && self.c[j - 2].is_ascii_digit()
        {
            j += 1;
            while j < n && is_ident_cont(self.c[j]) {
                j += 1;
            }
        }
        let text = self.slice(start, j);
        let line = self.line;
        self.push(line, Kind::Num, text);
        self.i = j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<(usize, String)> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| (t.line, t.text))
            .collect()
    }

    #[test]
    fn nested_block_comments_swallow_everything() {
        let src = "/* a /* thread_rng */ still comment */ real";
        let out = lex(src);
        assert_eq!(idents(src), vec![(1, "real".to_string())]);
        assert_eq!(out.comments.len(), 1);
        assert!(out.comments[0].text.contains("thread_rng"));
    }

    #[test]
    fn raw_strings_respect_hash_depth() {
        let src = r###"let a = r#"quote " inside"#; let b = r##"deep "# still"##;"###;
        let strs: Vec<String> = lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == Kind::Str)
            .map(|t| t.text)
            .collect();
        assert_eq!(strs, vec!["quote \" inside", "deep \"# still"]);
    }

    #[test]
    fn escaped_strings_do_not_leak_tokens() {
        let src = "let s = \"esc \\\" quote thread_rng\"; done";
        let names: Vec<String> = idents(src).into_iter().map(|(_, t)| t).collect();
        assert_eq!(names, vec!["let", "s", "done"]);
    }

    #[test]
    fn chars_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let q = '\\''; let d = '\"'; let s: &'static str = x; }";
        let out = lex(src);
        let lifetimes: Vec<String> = out
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Lifetime)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a", "'static"]);
        let chars: Vec<String> = out
            .toks
            .iter()
            .filter(|t| t.kind == Kind::Char)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(chars, vec!["'\\''", "'\"'"]);
    }

    #[test]
    fn byte_and_c_strings_take_the_string_path() {
        let src = "let a = b\"bytes \\\" x\"; let b = c\"cstr\"; let c = br#\"raw\"#; tail";
        let names: Vec<String> = idents(src).into_iter().map(|(_, t)| t).collect();
        assert_eq!(names, vec!["let", "a", "let", "b", "let", "c", "tail"]);
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "/* one\ntwo */\nlet x = \"a\nb\";\nfinal";
        let got = idents(src);
        assert_eq!(
            got,
            vec![
                (3, "let".to_string()),
                (3, "x".to_string()),
                (5, "final".to_string()),
            ]
        );
    }

    #[test]
    fn numbers_consume_exponents_and_suffixes() {
        let src = "let a = 1.5e-3; let b = 0x1f_u64; let c = 2E+8;";
        let nums: Vec<String> = lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == Kind::Num)
            .map(|t| t.text)
            .collect();
        assert_eq!(nums, vec!["1.5e-3", "0x1f_u64", "2E+8"]);
    }

    #[test]
    fn unterminated_string_consumes_to_eof() {
        let src = "let s = \"never closed\nmore";
        let out = lex(src);
        let strs: Vec<String> = out
            .toks
            .into_iter()
            .filter(|t| t.kind == Kind::Str)
            .map(|t| t.text)
            .collect();
        assert_eq!(strs, vec!["never closed\nmore"]);
    }
}
