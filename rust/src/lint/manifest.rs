//! Rule manifest (`rust/lint.rules`): named path zones + one binding per
//! rule. Parsing is fail-closed — an unknown rule id, an unknown mode, a
//! binding that references an undeclared zone, or a known rule left
//! unbound all reject the manifest, so a typo can never silently disable
//! a check. Grammar (line-based, whitespace-split, `#` comments):
//!
//! ```text
//! zone <name> <path-prefix> [<path-prefix>...]
//! rule <id> forbid-in <zone> | forbid-outside <zone>
//!          | forbid-everywhere | hotpath | cargo
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Every rule id the engine implements. A manifest must bind all of them.
pub const KNOWN_RULES: &[&str] = &[
    "wall-clock",
    "thread-rng",
    "nan-cmp",
    "map-iteration",
    "hex-u64",
    "hotpath-lock",
    "hotpath-alloc",
    "unsafe-safety",
    "delimiters",
    "cargo-offline",
];

/// Where a rule applies. `Hotpath` rules fire only inside
/// `// lint: hotpath(begin, …)` regions; `Cargo` rules run over
/// `Cargo.toml` instead of the source tree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Mode {
    ForbidIn(String),
    ForbidOutside(String),
    ForbidEverywhere,
    Hotpath,
    Cargo,
}

/// Parsed manifest: zone name → path prefixes, rule id → binding.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub zones: BTreeMap<String, Vec<String>>,
    pub bindings: BTreeMap<String, Mode>,
}

impl Manifest {
    pub fn parse(text: &str, origin: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (ln0, raw) in text.lines().enumerate() {
            let ln = ln0 + 1;
            let s = raw.trim();
            if s.is_empty() || s.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = s.split_whitespace().collect();
            match parts[0] {
                "zone" if parts.len() >= 3 => {
                    let prefixes = parts[2..].iter().map(|p| p.to_string()).collect();
                    m.zones.insert(parts[1].to_string(), prefixes);
                }
                "rule" if parts.len() >= 3 => {
                    let (rule, mode) = (parts[1], parts[2]);
                    if !KNOWN_RULES.contains(&rule) {
                        bail!("{origin}:{ln}: unknown rule '{rule}'");
                    }
                    let parsed = match mode {
                        "forbid-everywhere" => Mode::ForbidEverywhere,
                        "hotpath" => Mode::Hotpath,
                        "cargo" => Mode::Cargo,
                        "forbid-in" | "forbid-outside" => {
                            if parts.len() != 4 {
                                bail!("{origin}:{ln}: mode '{mode}' needs a zone");
                            }
                            if mode == "forbid-in" {
                                Mode::ForbidIn(parts[3].to_string())
                            } else {
                                Mode::ForbidOutside(parts[3].to_string())
                            }
                        }
                        other => bail!("{origin}:{ln}: unknown mode '{other}'"),
                    };
                    m.bindings.insert(rule.to_string(), parsed);
                }
                _ => bail!("{origin}:{ln}: unparseable line: {s}"),
            }
        }
        let missing: Vec<&str> = KNOWN_RULES
            .iter()
            .copied()
            .filter(|r| !m.bindings.contains_key(*r))
            .collect();
        if !missing.is_empty() {
            bail!("{origin}: unbound rules (fail-closed): {missing:?}");
        }
        for (rule, mode) in &m.bindings {
            let zone = match mode {
                Mode::ForbidIn(z) | Mode::ForbidOutside(z) => Some(z),
                _ => None,
            };
            if let Some(z) = zone {
                if !m.zones.contains_key(z) {
                    bail!("{origin}: rule '{rule}' binds undeclared zone '{z}'");
                }
            }
        }
        Ok(m)
    }

    /// Does repo-relative path `rel` fall under any prefix of `zone`?
    pub fn in_zone(&self, zone: &str, rel: &str) -> bool {
        match self.zones.get(zone) {
            Some(prefixes) => prefixes.iter().any(|p| rel.starts_with(p.as_str())),
            None => false,
        }
    }

    /// Is `rule` active for `rel`? `Hotpath`/`Cargo` bindings return
    /// false — they are dispatched specially, not per-file.
    pub fn active(&self, rule: &str, rel: &str) -> bool {
        match self.bindings.get(rule) {
            Some(Mode::ForbidEverywhere) => true,
            Some(Mode::ForbidIn(z)) => self.in_zone(z, rel),
            Some(Mode::ForbidOutside(z)) => !self.in_zone(z, rel),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full(extra: &str) -> String {
        format!(
            "zone hot a/ b/\n\
             rule wall-clock forbid-outside hot\n\
             rule thread-rng forbid-everywhere\n\
             rule nan-cmp forbid-everywhere\n\
             rule map-iteration forbid-in hot\n\
             rule hex-u64 forbid-in hot\n\
             rule hotpath-lock hotpath\n\
             rule hotpath-alloc hotpath\n\
             rule unsafe-safety forbid-everywhere\n\
             rule delimiters forbid-everywhere\n\
             {extra}"
        )
    }

    #[test]
    fn parses_and_routes_zones() {
        let m = Manifest::parse(&full("rule cargo-offline cargo\n"), "t").unwrap();
        assert!(m.active("wall-clock", "c/x.rs"));
        assert!(!m.active("wall-clock", "a/x.rs"));
        assert!(m.active("map-iteration", "b/y.rs"));
        assert!(!m.active("map-iteration", "c/y.rs"));
        assert!(m.active("thread-rng", "anything.rs"));
        assert!(!m.active("hotpath-lock", "a/x.rs"));
    }

    #[test]
    fn unbound_rule_is_rejected_fail_closed() {
        let err = Manifest::parse(&full(""), "t").unwrap_err().to_string();
        assert!(err.contains("unbound rules"), "{err}");
        assert!(err.contains("cargo-offline"), "{err}");
    }

    #[test]
    fn unknown_rule_mode_and_zone_are_rejected() {
        let text = full("rule cargo-offline cargo\nrule no-such forbid-everywhere\n");
        assert!(Manifest::parse(&text, "t").is_err());
        let text = full("rule cargo-offline frobnicate\n");
        assert!(Manifest::parse(&text, "t").is_err());
        let text = full("rule cargo-offline forbid-in nowhere\n");
        assert!(Manifest::parse(&text, "t").is_err());
    }
}
