//! Replica-pool executor integration tests — no artifacts needed, pure
//! L3. These run the *real* pool subsystem (ReplicaPool scheduler, state
//! buffer, action mailboxes with try_take/wait_any, striped swap with
//! pooled barrier parties, real environments with injected step-time
//! delays) against a stand-in actor fleet whose actions are a pure
//! function of `(obs, executor-drawn seed)` — exactly the determinism
//! contract the PJRT actors uphold (deferred randomness, DESIGN.md §4).
//!
//! The tentpole obligation (ISSUE 2 / paper Tab. 4 strengthened): for a
//! fixed seed, the per-replica trajectory signatures AND the gathered
//! `[T, B]` training batches must be bit-identical across every
//! `(n_threads, K)` factorization of `n_envs` and across actor counts.

use std::sync::Arc;

use hts_rl::buffers::{ActionBuffer, RolloutStorage, StateBuffer, StripedSwap};
use hts_rl::coordinator::common::Fnv;
use hts_rl::envs::{EnvSpec, StepTimeModel};
use hts_rl::executor::harness::{
    drive_learner_barrier, spawn_standin_actors, StandInPolicy,
};
use hts_rl::executor::{PoolShared, ReplicaPool};
use hts_rl::metrics::report::{SpsMeter, Stopwatch};
use hts_rl::rng::gumbel_argmax;
use hts_rl::telemetry::{TelemetryReport, TelemetryScope};
use hts_rl::trace::{attribute, Mode, Ph, TraceSink, DEFAULT_CAP};

/// Deterministic stand-in policy: logits are a pure function of the
/// observation, the sampled action a pure function of (logits, seed).
fn fake_logits(obs: &[f32], act_dim: usize) -> Vec<f32> {
    (0..act_dim)
        .map(|j| {
            obs.iter()
                .enumerate()
                .map(|(i, &x)| x * ((i + j + 1) as f32 * 0.13))
                .sum()
        })
        .collect()
}

/// FNV hash of every buffer of the gathered `[T, B]` view — "bit
/// identical" means these collide across factorizations.
fn hash_storage(s: &RolloutStorage) -> u64 {
    let mut f = Fnv::default();
    for &x in &s.obs {
        f.update(x.to_bits() as u64);
    }
    for &a in &s.act {
        f.update(a as u64);
    }
    for &r in &s.rew {
        f.update(r.to_bits() as u64);
    }
    for &d in &s.done {
        f.update(d.to_bits() as u64);
    }
    for &o in &s.last_obs {
        f.update(o.to_bits() as u64);
    }
    f.finish()
}

struct HarnessOut {
    /// XOR of all replica trajectory signatures.
    signature: u64,
    /// Per-iteration hash of the gathered train view.
    batch_hashes: Vec<u64>,
}

/// Run `iters` full iterations of the executor/actor/swap machinery with
/// `n_envs / k` pool threads of K replicas each, mirroring the HTS
/// driver's protocol (including its shutdown sequence). Also merges and
/// returns the run's telemetry (empty unless `telemetry` is on); when a
/// `trace` sink is supplied, every pool and actor thread records into it
/// and the caller reads the merged report off the sink afterwards.
#[allow(clippy::too_many_arguments)]
fn run_harness_core(
    policy: StandInPolicy,
    env: &str,
    n_agents: usize,
    steptime: StepTimeModel,
    n_envs: usize,
    k: usize,
    n_actors: usize,
    alpha: usize,
    iters: u64,
    seed: u64,
    telemetry: bool,
    trace: Option<&Arc<TraceSink>>,
) -> (HarnessOut, TelemetryReport) {
    assert_eq!(n_envs % k, 0, "K must divide n_envs");
    let spec = EnvSpec::by_name(env)
        .unwrap()
        .with_agents(n_agents)
        .unwrap()
        .with_steptime(steptime);
    let obs_dim = spec.build().unwrap().obs_dim();
    let b_cols = n_envs * n_agents;
    let n_threads = n_envs / k;
    let swap = Arc::new(StripedSwap::with_parties(
        alpha, b_cols, obs_dim, n_envs, n_threads,
    ));
    let state_buf = Arc::new(StateBuffer::with_telemetry(telemetry));
    let act_buf = Arc::new(ActionBuffer::new(b_cols));
    let sps = Arc::new(SpsMeter::new());
    let watch = Stopwatch::new();

    let actor_handles = spawn_standin_actors(
        n_actors, &state_buf, &act_buf, b_cols, &policy, telemetry, trace,
    );

    let mut pool_handles = Vec::new();
    for t in 0..n_threads {
        let spec = spec.clone();
        let shared = PoolShared {
            swap: swap.clone(),
            state_buf: state_buf.clone(),
            act_buf: act_buf.clone(),
            sps: sps.clone(),
            watch,
            col_offset: 0,
            telemetry,
            trace: trace.cloned(),
        };
        pool_handles.push(std::thread::spawn(move || {
            ReplicaPool::new(&spec, seed, alpha, t * k..(t + 1) * k, shared)
                .unwrap()
                .run()
                .unwrap()
        }));
    }

    // Learner stand-in: two-phase barrier, gather, hash each iteration's
    // view inside the publication window (HTS shutdown sequence).
    let mut gathered = RolloutStorage::new(alpha, b_cols, obs_dim);
    let mut batch_hashes = Vec::new();
    drive_learner_barrier(
        &swap,
        &state_buf,
        &act_buf,
        &mut gathered,
        iters,
        |view| batch_hashes.push(hash_storage(view)),
    );

    let mut signature = 0u64;
    let mut tel = TelemetryScope::new(telemetry);
    for h in pool_handles {
        let report = h.join().unwrap();
        signature ^= report.signature;
        tel.merge(&report.telemetry);
    }
    for h in actor_handles {
        tel.merge(&h.join().unwrap());
    }
    tel.merge(&state_buf.telemetry());
    (HarnessOut { signature, batch_hashes }, tel.report())
}

/// Telemetry-free entry point used by the signature/invariance tests.
#[allow(clippy::too_many_arguments)]
fn run_harness_with(
    policy: StandInPolicy,
    env: &str,
    n_agents: usize,
    steptime: StepTimeModel,
    n_envs: usize,
    k: usize,
    n_actors: usize,
    alpha: usize,
    iters: u64,
    seed: u64,
) -> HarnessOut {
    run_harness_core(
        policy, env, n_agents, steptime, n_envs, k, n_actors, alpha, iters,
        seed, false, None,
    )
    .0
}

/// The historical harness entry point: deterministic gumbel stand-in
/// actors over `fake_logits`.
#[allow(clippy::too_many_arguments)]
fn run_harness(
    env: &str,
    n_agents: usize,
    steptime: StepTimeModel,
    n_envs: usize,
    k: usize,
    n_actors: usize,
    alpha: usize,
    iters: u64,
    seed: u64,
) -> HarnessOut {
    let act_dim = EnvSpec::by_name(env).unwrap().build().unwrap().act_dim();
    let policy: StandInPolicy = Arc::new(move |obs, seed| {
        gumbel_argmax(&fake_logits(obs, act_dim), seed)
    });
    run_harness_with(
        policy, env, n_agents, steptime, n_envs, k, n_actors, alpha, iters,
        seed,
    )
}

/// The tentpole acceptance test: n_envs = 8 across every factorization
/// K ∈ {1, 2, 4, 8} — 8×1, 4×2, 2×4, 1×8 threads×replicas — produces
/// bit-identical signatures and training batches. This is also a
/// cross-*implementation* check, not just pool-vs-pool: the K = 1
/// baseline runs `ReplicaPool::run_single`, the classic blocking
/// executor loop (per-slot condvar waits, slept delays), while K > 1
/// runs the multiplexed deadline scheduler — the two code paths must
/// agree bit-for-bit.
#[test]
fn pool_bit_identical_across_factorizations() {
    let base = run_harness(
        "catch", 1, StepTimeModel::None, 8, 1, 2, 5, 4, 42,
    );
    for k in [2usize, 4, 8] {
        let r = run_harness(
            "catch", 1, StepTimeModel::None, 8, k, 2, 5, 4, 42,
        );
        assert_eq!(base.signature, r.signature, "signature diverged, K={k}");
        assert_eq!(
            base.batch_hashes, r.batch_hashes,
            "gathered [T, B] batches diverged, K={k}"
        );
    }
}

/// Same invariance with injected engine latency — exercising the
/// deadline-based cooking path (virtual deadlines, park-until-earliest
/// scheduling) — and simultaneously sweeping the actor count.
#[test]
fn pool_invariant_under_delays_and_actor_sweep() {
    let st = StepTimeModel::Gamma { shape: 2.0, mean_us: 150.0 };
    let base = run_harness("catch", 1, st, 8, 1, 1, 5, 3, 7);
    for (k, n_actors) in [(2usize, 3usize), (4, 1), (8, 2)] {
        let r = run_harness("catch", 1, st, 8, k, n_actors, 5, 3, 7);
        assert_eq!(
            base.signature, r.signature,
            "signature diverged at K={k} actors={n_actors}"
        );
        assert_eq!(
            base.batch_hashes, r.batch_hashes,
            "batches diverged at K={k} actors={n_actors}"
        );
    }
}

/// Multi-agent replicas: each replica owns `n_agents` batch columns and
/// its pool must collect one action per agent before cooking.
#[test]
fn pool_invariant_multi_agent() {
    let st = StepTimeModel::Exponential { mean_us: 100.0 };
    let base = run_harness(
        "football/3_vs_1_with_keeper", 2, st, 4, 1, 2, 5, 3, 11,
    );
    for k in [2usize, 4] {
        let r = run_harness(
            "football/3_vs_1_with_keeper", 2, st, 4, k, 2, 5, 3, 11,
        );
        assert_eq!(base.signature, r.signature, "multi-agent sig, K={k}");
        assert_eq!(base.batch_hashes, r.batch_hashes, "batches, K={k}");
    }
}

/// ISSUE 3 satellite: the PR 2 trajectory semantics survive the flat
/// observation-plane API swap, pinned to absolute values. The constants
/// were computed by an exact integer transliteration of the *pre-swap*
/// executor protocol (`python/tools/pin_signatures.py`): SplitMix64
/// streams 1000/2000+r, calm Catch dynamics, FNV signature update order
/// (action, reward bits, done — then on-done reset), and the gathered
/// `[T, B]` hash. The stand-in policy is `seed % act_dim` rather than
/// the gumbel policy so every quantity is integer or exactly
/// representable — the pins are bit-portable across platforms and libm
/// versions. Any draw-order or layout regression in the new API moves
/// these values.
#[test]
fn pool_signatures_pinned() {
    const PINNED_SIGNATURE: u64 = 0xc9567d1a817f0564;
    const PINNED_BATCH_HASHES: [u64; 4] = [
        0x60ff0bc8027ea625,
        0xd7df0c258c254067,
        0xf806391c6f0ab8e4,
        0x505165e9ed735ea6,
    ];
    for k in [1usize, 2, 4, 8] {
        let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 3) as usize);
        let r = run_harness_with(
            policy, "catch", 1, StepTimeModel::None, 8, k, 2, 5, 4, 42,
        );
        assert_eq!(
            r.signature, PINNED_SIGNATURE,
            "PR 2 signature regressed at K={k}"
        );
        assert_eq!(
            r.batch_hashes,
            PINNED_BATCH_HASHES.to_vec(),
            "PR 2 gathered [T, B] bytes regressed at K={k}"
        );
    }
}

/// ISSUE 4 tentpole: the multi-agent gridworld family through the pool —
/// factorization invariance with injected delays and an actor sweep,
/// exercising per-agent mailboxes, the slip RNG draws, and the
/// agent-major plane on a cheap non-football multi-agent workload.
#[test]
fn pool_invariant_team_gridworld() {
    let st = StepTimeModel::Exponential { mean_us: 80.0 };
    let base = run_harness(
        "gridworld_team/gather?slip=0.15", 2, st, 4, 1, 2, 5, 3, 13,
    );
    for (k, n_actors) in [(2usize, 1usize), (4, 3)] {
        let r = run_harness(
            "gridworld_team/gather?slip=0.15", 2, st, 4, k, n_actors, 5, 3,
            13,
        );
        assert_eq!(
            base.signature, r.signature,
            "team sig diverged, K={k} actors={n_actors}"
        );
        assert_eq!(
            base.batch_hashes, r.batch_hashes,
            "team batches diverged, K={k} actors={n_actors}"
        );
    }
}

/// ISSUE 4 acceptance: integer-exact pins for the new multi-agent
/// gridworld family across every (n_threads, K) factorization of
/// n_envs = 8, K ∈ {1, 2, 4, 8}. The constants come from the same
/// independent transliteration that pins catch
/// (`python/tools/pin_signatures.py` — which still reproduces the PR 3
/// catch constants above, proving the existing families' signatures are
/// byte-identical). TeamGridWorld's observation and reward values are
/// all exactly representable (0 / ±0.5 / ±1 / k·0.25 / k/8 / the
/// constant −0.01), so these pins are bit-portable too. The slip=0.15
/// parameter makes each agent's step draw from the env stream, so any
/// draw-order regression in the multi-agent path moves these values.
#[test]
fn team_gridworld_signatures_pinned() {
    const PINNED_SIGNATURE: u64 = 0x9a123a8e466ba605;
    const PINNED_BATCH_HASHES: [u64; 4] = [
        0xc60afb8c8caad2d0,
        0xb460b78aa8a8d3ab,
        0xa54cee67ac83df3e,
        0xd8718bf4cb3a393b,
    ];
    for k in [1usize, 2, 4, 8] {
        let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 4) as usize);
        let r = run_harness_with(
            policy,
            "gridworld_team/gather?slip=0.15",
            2,
            StepTimeModel::None,
            8,
            k,
            2,
            5,
            4,
            42,
        );
        assert_eq!(
            r.signature, PINNED_SIGNATURE,
            "team gridworld signature regressed at K={k}"
        );
        assert_eq!(
            r.batch_hashes,
            PINNED_BATCH_HASHES.to_vec(),
            "team gridworld gathered [T, B] bytes regressed at K={k}"
        );
    }
}

/// ISSUE 6 acceptance: lane-width invariance, pinned to absolute values.
/// n_envs = 32 so the harness can be factored as W ∈ {1, 8, 32} lanes
/// per pool — W = 1 runs the classic blocking loop, W = 8 / 32 run the
/// multiplexed scheduler whose lockstep path steps the whole SoA lane
/// group in one batched `VecEnv` call and publishes one group message.
/// The constants come from the same sequential transliteration that
/// pins the n_envs = 8 runs above (`python/tools/pin_signatures.py`,
/// lane-width block): per-lane streams key on the global replica index
/// and each lane draws in scalar order, so the pin is width-independent
/// by construction — any SoA drift in a vectorized family (catch here;
/// the multi-agent team family below) moves these values and fails CI
/// naming the family.
#[test]
fn lane_width_signatures_pinned() {
    const LANE_CATCH_SIGNATURE: u64 = 0xeef518d3914ac0b5;
    const LANE_CATCH_BATCH_HASHES: [u64; 4] = [
        0x182b2da035376646,
        0x8c9113539573b625,
        0x1a02f78d7251f2c7,
        0xd68fdf3b63611525,
    ];
    const LANE_TEAM_SIGNATURE: u64 = 0xbbcb74ac3c47edf0;
    const LANE_TEAM_BATCH_HASHES: [u64; 4] = [
        0x2a3e6c6e52771145,
        0x550180d08f014187,
        0xad018b1bed8a6d76,
        0xb0a765657eb3c323,
    ];
    for w in [1usize, 8, 32] {
        let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 3) as usize);
        let r = run_harness_with(
            policy, "catch", 1, StepTimeModel::None, 32, w, 2, 5, 4, 42,
        );
        assert_eq!(
            r.signature, LANE_CATCH_SIGNATURE,
            "catch lane signature drifted at W={w}"
        );
        assert_eq!(
            r.batch_hashes,
            LANE_CATCH_BATCH_HASHES.to_vec(),
            "catch gathered [T, B] bytes drifted at W={w}"
        );
        let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 4) as usize);
        let r = run_harness_with(
            policy,
            "gridworld_team/gather?slip=0.15",
            2,
            StepTimeModel::None,
            32,
            w,
            2,
            5,
            4,
            42,
        );
        assert_eq!(
            r.signature, LANE_TEAM_SIGNATURE,
            "gridworld_team lane signature drifted at W={w}"
        );
        assert_eq!(
            r.batch_hashes,
            LANE_TEAM_BATCH_HASHES.to_vec(),
            "gridworld_team gathered [T, B] bytes drifted at W={w}"
        );
    }
}

/// Different seeds must still produce different runs through the pool
/// (the invariance above is not a constant-output artifact).
#[test]
fn pool_seed_sensitivity() {
    let a = run_harness("catch", 1, StepTimeModel::None, 4, 2, 1, 5, 2, 1);
    let b = run_harness("catch", 1, StepTimeModel::None, 4, 2, 1, 5, 2, 2);
    assert_ne!(a.signature, b.signature);
}

/// PR 7 tentpole acceptance: turning telemetry on must not move a single
/// bit of the run — same pinned signature, same gathered `[T, B]` bytes —
/// across the solo (K = 1), multiplexed (K = 4), and lane-group (W = 8)
/// executor paths. Counters are observation only: no extra RNG draws, no
/// reordered steps, no changed message sizes.
#[test]
fn telemetry_does_not_move_signatures() {
    for k in [1usize, 4, 8] {
        let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 3) as usize);
        let (off, off_tel) = run_harness_core(
            policy.clone(), "catch", 1, StepTimeModel::None, 8, k, 2, 5, 4,
            42, false, None,
        );
        let (on, on_tel) = run_harness_core(
            policy, "catch", 1, StepTimeModel::None, 8, k, 2, 5, 4, 42, true,
            None,
        );
        assert_eq!(
            off.signature, on.signature,
            "telemetry moved the signature at K={k}"
        );
        assert_eq!(
            off.batch_hashes, on.batch_hashes,
            "telemetry moved the gathered [T, B] bytes at K={k}"
        );
        // ... and against the absolute pin, not just each other.
        assert_eq!(on.signature, 0xc9567d1a817f0564);
        // A disabled run reports nothing at all.
        assert_eq!(off_tel, TelemetryReport::default());
        assert!(on_tel.counter("steps_total") > 0);
    }
}

/// Structural sanity of the executor counters: every environment step is
/// exactly one of solo / lockstep-lane / degraded; the actors' batched
/// grabs carry at least one mailbox column each; and the state buffer's
/// free-list accounting covers every rent.
#[test]
fn telemetry_counters_are_structurally_consistent() {
    let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 3) as usize);
    let (_, tel) = run_harness_core(
        policy, "catch", 1, StepTimeModel::None, 8, 4, 2, 5, 4, 42, true,
        None,
    );
    let steps = tel.counter("steps_total");
    assert!(steps > 0, "no steps counted");
    assert_eq!(
        tel.counter("solo_steps")
            + tel.counter("lockstep_lane_steps")
            + tel.counter("degraded_steps"),
        steps,
        "step-mode counters must partition steps_total"
    );
    let grabs = tel.counter("grab_batches");
    assert!(grabs > 0, "actors never grabbed a batch");
    assert!(
        tel.counter("grab_columns") >= grabs,
        "every grab batch carries at least one column"
    );
    assert!(
        tel.counter("grab_messages") <= tel.counter("grab_columns"),
        "a message covers one or more columns"
    );
    // Free lists: every hit or miss corresponds to one rented buffer.
    assert!(
        tel.counter("freelist_hits") + tel.counter("freelist_misses") > 0,
        "state buffer never rented"
    );
}

/// ISSUE 2 satellite: a pool executor parked in `wait_any` (its replicas'
/// actions will never arrive — there are no actors) must wake on close
/// and unwind cleanly instead of hanging.
#[test]
fn pool_parked_executor_wakes_on_close() {
    let spec = EnvSpec::by_name("catch").unwrap();
    let obs_dim = spec.build().unwrap().obs_dim();
    let swap = Arc::new(StripedSwap::with_parties(4, 2, obs_dim, 2, 1));
    let state_buf = Arc::new(StateBuffer::new());
    let act_buf = Arc::new(ActionBuffer::new(2));
    let shared = PoolShared {
        swap: swap.clone(),
        state_buf: state_buf.clone(),
        act_buf: act_buf.clone(),
        sps: Arc::new(SpsMeter::new()),
        watch: Stopwatch::new(),
        col_offset: 0,
        telemetry: false,
        trace: None,
    };
    let h = std::thread::spawn(move || {
        ReplicaPool::new(&spec, 3, 4, 0..2, shared).unwrap().run().unwrap()
    });
    std::thread::sleep(std::time::Duration::from_millis(30));
    state_buf.close();
    act_buf.close();
    swap.shutdown();
    let report = h.join().unwrap(); // would hang forever on a wakeup bug
    assert_eq!(report.episodes.len(), 0, "no step could have completed");
}

/// ISSUE 10 tentpole acceptance: arming the event tracer must not move a
/// single bit of the run — same pinned signature, same gathered `[T, B]`
/// bytes — across the solo (K = 1), multiplexed (K = 4), and lane-group
/// (W = 8) executor paths, exactly like telemetry above. Recording is
/// thread-owned and observation-only: no extra RNG draws, no reordered
/// steps, no changed message sizes. The traced run must also actually
/// *record*: a non-empty report whose spans are balanced per thread.
#[test]
fn tracing_does_not_move_signatures() {
    for k in [1usize, 4, 8] {
        let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 3) as usize);
        let (off, _) = run_harness_core(
            policy.clone(), "catch", 1, StepTimeModel::None, 8, k, 2, 5, 4,
            42, false, None,
        );
        let sink = TraceSink::new(Mode::Full { cap: DEFAULT_CAP });
        let (on, _) = run_harness_core(
            policy, "catch", 1, StepTimeModel::None, 8, k, 2, 5, 4, 42,
            false, Some(&sink),
        );
        assert_eq!(
            off.signature, on.signature,
            "tracing moved the signature at K={k}"
        );
        assert_eq!(
            off.batch_hashes, on.batch_hashes,
            "tracing moved the gathered [T, B] bytes at K={k}"
        );
        // ... and against the absolute pin, not just each other.
        assert_eq!(on.signature, 0xc9567d1a817f0564);
        let rep = sink.report();
        assert!(
            rep.total_events() > 0,
            "traced run deposited no events at K={k}"
        );
        // 8/k pool threads + 2 actor threads all deposited.
        assert_eq!(rep.threads.len(), 8 / k + 2, "missing tracks at K={k}");
        for t in &rep.threads {
            let begins =
                t.events.iter().filter(|e| e.ph == Ph::Begin).count();
            let ends = t.events.iter().filter(|e| e.ph == Ph::End).count();
            assert_eq!(
                begins, ends,
                "unbalanced spans on {} at K={k}",
                t.track.label()
            );
            assert_eq!(t.dropped, 0, "events dropped at K={k}");
        }
    }
}

/// ISSUE 10 acceptance: barrier stall attribution on a delay-model pool
/// names the injected straggler. Four K = 1 pools, replica 0 alone given
/// a 2 ms constant engine delay — every iteration the other three
/// executors arrive at the swap barrier and wait on it, so the ranked
/// attribution must charge replica 0 first, in (nearly) every iteration.
#[test]
fn attribution_names_the_injected_straggler() {
    let n_envs = 4usize;
    let alpha = 3usize;
    let iters = 4u64;
    let policy: StandInPolicy = Arc::new(|_obs, seed| (seed % 3) as usize);
    let sink = TraceSink::new(Mode::Full { cap: DEFAULT_CAP });
    let base = EnvSpec::by_name("catch").unwrap().with_agents(1).unwrap();
    let obs_dim = base.build().unwrap().obs_dim();
    let b_cols = n_envs;
    let swap = Arc::new(StripedSwap::with_parties(
        alpha, b_cols, obs_dim, n_envs, n_envs,
    ));
    let state_buf = Arc::new(StateBuffer::new());
    let act_buf = Arc::new(ActionBuffer::new(b_cols));
    let sps = Arc::new(SpsMeter::new());
    let watch = Stopwatch::new();
    let actor_handles = spawn_standin_actors(
        2, &state_buf, &act_buf, b_cols, &policy, false, Some(&sink),
    );
    let mut pool_handles = Vec::new();
    for t in 0..n_envs {
        // the straggler: pool 0 (owning replica 0) pays 2 ms per step
        let st = if t == 0 {
            StepTimeModel::Constant { us: 2000.0 }
        } else {
            StepTimeModel::None
        };
        let spec = base.clone().with_steptime(st);
        let shared = PoolShared {
            swap: swap.clone(),
            state_buf: state_buf.clone(),
            act_buf: act_buf.clone(),
            sps: sps.clone(),
            watch,
            col_offset: 0,
            telemetry: false,
            trace: Some(sink.clone()),
        };
        pool_handles.push(std::thread::spawn(move || {
            ReplicaPool::new(&spec, 42, alpha, t..t + 1, shared)
                .unwrap()
                .run()
                .unwrap()
        }));
    }
    let mut gathered = RolloutStorage::new(alpha, b_cols, obs_dim);
    drive_learner_barrier(
        &swap, &state_buf, &act_buf, &mut gathered, iters, |_| {},
    );
    for h in pool_handles {
        h.join().unwrap();
    }
    for h in actor_handles {
        h.join().unwrap();
    }
    let att = attribute::attribute(&sink.report());
    assert!(att.iterations > 0, "no barrier iterations attributed");
    let top = att.stalls.first().expect("no stall rows");
    assert_eq!(
        top.replica, 0,
        "the injected straggler (replica 0, 2 ms/step) must top the \
         stall ranking, got {:?}",
        att.stalls
    );
    assert!(top.charged_ns > 0, "straggler charged zero wait");
    assert!(
        top.straggles >= att.iterations / 2,
        "replica 0 should arrive last in most iterations: {:?}",
        att
    );
}
