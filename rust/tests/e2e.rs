//! End-to-end integration: all three drivers run the full stack
//! (envs → buffers → PJRT inference → storage → PJRT train step) and
//! HTS-RL actually *learns* on a real workload.

use hts_rl::algo::{Algo, AlgoConfig};
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;
use hts_rl::metrics::evaluate_params;

fn have_artifacts() -> bool {
    hts_rl::coordinator::common::default_artifacts_dir()
        .join("manifest.json")
        .exists()
}

fn base(env: &str, algo: Algo) -> RunConfig {
    let spec = EnvSpec::by_name(env).unwrap();
    let mut c = RunConfig::new(spec, AlgoConfig::a2c(algo));
    c.n_envs = 16;
    c.n_actors = 2;
    c.stop = StopCond::updates(5);
    c
}

#[test]
fn all_three_drivers_complete() {
    if !have_artifacts() {
        return;
    }
    for (method, algo) in [
        (Method::Hts, Algo::A2cDelayed),
        (Method::Sync, Algo::A2cDelayed),
        (Method::Async, Algo::Vtrace),
    ] {
        let r = run(method, &base("catch", algo)).unwrap();
        assert!(r.steps > 0, "{method:?}");
        assert!(r.updates >= 5, "{method:?}");
        assert!(r.final_loss.is_finite(), "{method:?}");
        assert!(r.sps() > 0.0, "{method:?}");
    }
}

#[test]
fn async_driver_tolerates_uneven_producers() {
    // Regression: a fast env replica can contribute two trajectories to
    // one learner batch while a slow one contributes none — the learner
    // must assign storage columns by batch slot, not env id.
    if !have_artifacts() {
        return;
    }
    let spec = EnvSpec::by_name("catch")
        .unwrap()
        // high-variance step times make producer rates very uneven
        .with_steptime(hts_rl::envs::StepTimeModel::Gamma {
            shape: 0.5,
            mean_us: 500.0,
        });
    let mut cfg = RunConfig::new(spec, AlgoConfig::a2c(Algo::Vtrace));
    cfg.n_envs = 16; // must match the train artifact batch
    cfg.n_actors = 2;
    cfg.stop = StopCond::updates(12);
    let r = run(Method::Async, &cfg).unwrap();
    assert!(r.updates >= 12);
}

#[test]
fn async_driver_measures_staleness() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = base("catch", Algo::Vtrace);
    cfg.stop = StopCond::updates(10);
    let r = run(Method::Async, &cfg).unwrap();
    assert!(!r.staleness.is_empty());
    // some trajectories must be at least one update stale
    assert!(r.staleness.iter().any(|&s| s >= 1.0));
}

#[test]
fn multi_agent_columns_work() {
    if !have_artifacts() {
        return;
    }
    let spec = EnvSpec::by_name("football/3_vs_1_with_keeper?agents=3")
        .unwrap();
    let mut cfg = RunConfig::new(spec, AlgoConfig::ppo());
    cfg.n_envs = 4; // 4 envs × 3 agents = 12 columns (B=12 artifact)
    cfg.n_actors = 2;
    cfg.stop = StopCond::updates(3);
    let r = run(Method::Hts, &cfg).unwrap();
    assert!(r.updates >= 3);
}

#[test]
fn hts_learns_catch() {
    // The real E2E check: HTS-RL(A2C) on Catch must clearly beat the
    // random policy (~0 expected reward; optimal = 1) after a short run.
    if !have_artifacts() {
        return;
    }
    let mut cfg = base("catch", Algo::A2cDelayed);
    cfg.seed = 3;
    cfg.stop = StopCond::steps(25_000);
    let r = run(Method::Hts, &cfg).unwrap();

    // evaluate the final policy directly
    let manifest = hts_rl::model::manifest::Manifest::load(&cfg.artifacts)
        .unwrap();
    let rt = hts_rl::runtime::ModelRuntime::new(manifest).unwrap();
    // final params are not exported by the report; use training episodes
    let _ = rt;
    let tail: Vec<f64> = r
        .episodes
        .iter()
        .rev()
        .take(200)
        .map(|e| e.reward)
        .collect();
    let head: Vec<f64> = r.episodes.iter().take(200)
        .map(|e| e.reward).collect();
    let tail_mean = hts_rl::stats::mean(&tail);
    let head_mean = hts_rl::stats::mean(&head);
    assert!(
        tail_mean > head_mean + 0.3 && tail_mean > 0.3,
        "no learning: head {head_mean:.2} → tail {tail_mean:.2}"
    );
}

#[test]
fn eval_protocol_is_deterministic() {
    if !have_artifacts() {
        return;
    }
    let manifest = hts_rl::model::manifest::Manifest::load(
        hts_rl::coordinator::common::default_artifacts_dir(),
    )
    .unwrap();
    let rt = hts_rl::runtime::ModelRuntime::new(manifest).unwrap();
    let params = rt.init_params("catch", 5).unwrap();
    let pool = hts_rl::runtime::ForwardPool::new(&rt, "catch").unwrap();
    let spec = EnvSpec::by_name("catch").unwrap();
    let a = evaluate_params(&pool, &params, &spec, 10, 99).unwrap();
    let b = evaluate_params(&pool, &params, &spec, 10, 99).unwrap();
    assert_eq!(a, b);
    let c = evaluate_params(&pool, &params, &spec, 10, 100).unwrap();
    assert_ne!(a, c);
}
