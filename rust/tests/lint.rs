//! hts-lint acceptance (DESIGN.md §14): the tool self-hosts clean over
//! this very source tree, and every seeded violation in the fixture
//! corpus fires with the right rule id at the exact pinned line.
//!
//! `EXPECTED` below must stay identical to
//! `EXPECTED_FIXTURE_FINDINGS` in `python/tools/hts_lint.py` — the two
//! implementations are asserted against the same corpus so they cannot
//! drift apart silently.

use std::collections::BTreeSet;
use std::ffi::OsStr;
use std::path::{Path, PathBuf};

use hts_rl::lint::{self, baseline, manifest::Manifest, rules, LintConfig};

fn repo(p: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(p)
}

/// Pinned (file, line, rule) triples for the seeded-violation corpus.
const EXPECTED: &[(&str, usize, &str)] = &[
    ("artifact_maps.rs", 4, "map-iteration"),
    ("artifact_maps.rs", 5, "map-iteration"),
    ("clock_violation.rs", 4, "wall-clock"),
    ("clock_violation.rs", 7, "wall-clock"),
    ("delim_torn.rs", 9, "delimiters"),
    ("directive_errors.rs", 5, "lint-directive"),
    ("directive_errors.rs", 9, "lint-directive"),
    ("directive_errors.rs", 13, "lint-directive"),
    ("directive_errors.rs", 17, "lint-directive"),
    ("hotpath_discipline.rs", 11, "hotpath-lock"),
    ("hotpath_discipline.rs", 12, "hotpath-lock"),
    ("hotpath_discipline.rs", 13, "hotpath-alloc"),
    ("hotpath_discipline.rs", 14, "hotpath-alloc"),
    ("torture_lexer.rs", 27, "thread-rng"),
    ("torture_lexer.rs", 31, "nan-cmp"),
    ("torture_lexer.rs", 45, "unsafe-safety"),
    ("trace_ring.rs", 10, "wall-clock"),
    ("trace_ring.rs", 16, "hotpath-alloc"),
    ("wire_hex.rs", 6, "hex-u64"),
    ("wire_hex.rs", 10, "hex-u64"),
];

#[test]
fn fixtures_fire_exactly_where_pinned() {
    let dir = repo("tests/lint_fixtures");
    let mtext = std::fs::read_to_string(dir.join("fixture.rules")).unwrap();
    let man = Manifest::parse(&mtext, "fixture.rules").unwrap();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension() == Some(OsStr::new("rs")))
        .collect();
    paths.sort();
    let mut got: Vec<(String, usize, String)> = Vec::new();
    for p in &paths {
        let rel = p.file_name().unwrap().to_string_lossy().to_string();
        let src = std::fs::read_to_string(p).unwrap();
        let rep = rules::check_file(&rel, &src, &man);
        got.extend(rep.findings.into_iter().map(|f| (f.file, f.line, f.rule)));
    }
    got.sort();
    let expected: Vec<(String, usize, String)> = EXPECTED
        .iter()
        .map(|&(f, l, r)| (f.to_string(), l, r.to_string()))
        .collect();
    assert_eq!(got, expected);
}

/// The fail-closed acceptance gate: zero unbaselined findings over the
/// real tree with the committed manifest + baseline, no stale entries,
/// and an unsafe inventory confined to the two audited modules with
/// every site covered by a SAFETY comment.
#[test]
fn self_hosts_clean_over_the_real_tree() {
    let out = lint::run(&LintConfig {
        root: repo("src"),
        manifest: repo("lint.rules"),
        baseline: Some(repo("lint_baseline.json")),
        cargo: Some(repo("Cargo.toml")),
    })
    .expect("lint run over rust/src");
    assert!(out.files >= 70, "walk found too few files: {}", out.files);
    let rendered: Vec<String> = out
        .findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        out.findings.is_empty(),
        "unbaselined findings:\n{}",
        rendered.join("\n")
    );
    assert!(out.stale.is_empty(), "stale baseline entries: {:?}", out.stale);
    let files: BTreeSet<&str> = out.unsafe_sites.iter().map(|u| u.file.as_str()).collect();
    assert_eq!(
        files.into_iter().collect::<Vec<_>>(),
        ["buffers/double.rs", "perf/mod.rs"],
        "unsafe spread beyond the audited modules"
    );
    for u in &out.unsafe_sites {
        assert!(u.safety.is_some(), "uncovered unsafe at {}:{}", u.file, u.line);
    }
}

#[test]
fn cargo_offline_rule_flags_non_path_deps() {
    let toml = "[package]\nname = \"x\"\n[dependencies]\nserde = \"1.0\"\n\
                anyhow = { path = \"vendor/anyhow\" }\n\
                reqwest = { version = \"0.11\" }\n\
                mixed = { path = \"v/x\", git = \"https://example.com/x\" }\n";
    let findings = rules::check_cargo("Cargo.toml", toml);
    let lines: Vec<usize> = findings.iter().map(|f| f.line).collect();
    assert_eq!(lines, [4, 6, 7]);
    assert!(findings.iter().all(|f| f.rule == "cargo-offline"));
}

#[test]
fn baseline_absorbs_counts_and_reports_stale_entries() {
    let f = |file: &str, line: usize, excerpt: &str| rules::Finding {
        file: file.to_string(),
        line,
        rule: "map-iteration".to_string(),
        message: "m".to_string(),
        excerpt: excerpt.to_string(),
    };
    let findings = vec![f("a.rs", 3, "use HashMap;"), f("a.rs", 9, "use HashMap;")];
    let doc = baseline::render(&findings);
    let base = baseline::parse(&doc).unwrap();
    // Same excerpt twice -> one entry with count 2; both findings absorb.
    let diff = baseline::apply(findings.clone(), &base);
    assert!(diff.fresh.is_empty());
    assert_eq!(diff.baselined, 2);
    assert!(diff.stale.is_empty());
    // A third identical finding exceeds the count: fresh.
    let mut three = findings.clone();
    three.push(f("a.rs", 20, "use HashMap;"));
    let diff = baseline::apply(three, &base);
    assert_eq!(diff.fresh.len(), 1);
    // Line numbers are NOT part of the key: shifted findings still absorb.
    let shifted = vec![f("a.rs", 103, "use HashMap;"), f("a.rs", 109, "use HashMap;")];
    assert!(baseline::apply(shifted, &base).fresh.is_empty());
    // Nothing consumed -> the entry is stale with its full count.
    let diff = baseline::apply(Vec::new(), &base);
    assert_eq!(diff.baselined, 0);
    assert_eq!(diff.stale.len(), 1);
    assert_eq!(diff.stale[0].1, 2);
}

/// The committed manifest itself must parse (fail-closed: a typo in
/// `lint.rules` breaks this test, not just the CI step).
#[test]
fn committed_manifest_parses_and_zones_resolve() {
    let mtext = std::fs::read_to_string(repo("lint.rules")).unwrap();
    let man = Manifest::parse(&mtext, "lint.rules").unwrap();
    assert!(man.active("wall-clock", "coordinator/common.rs"));
    assert!(!man.active("wall-clock", "telemetry/mod.rs"));
    assert!(man.active("map-iteration", "executor/harness.rs"));
    assert!(!man.active("map-iteration", "buffers/double.rs"));
    assert!(man.active("hex-u64", "campaign/journal.rs"));
    assert!(!man.active("hex-u64", "util/json.rs"));
    // ISSUE 10: only the trace clock may read wall time; the rest of
    // the trace subsystem is policed like any other code, and its
    // export path sits inside the artifact zone.
    assert!(man.active("wall-clock", "trace/mod.rs"));
    assert!(!man.active("wall-clock", "trace/clock.rs"));
    assert!(man.active("map-iteration", "trace/export.rs"));
}
