//! System-level determinism tests — paper §4.1 ("maintains full
//! determinism") and Tab. 4 ("identical final average scores" across actor
//! counts). These run the real HTS-RL stack end to end.

use hts_rl::algo::{Algo, AlgoConfig};
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;

fn cfg(n_actors: usize, seed: u64) -> RunConfig {
    let spec = EnvSpec::by_name("catch").unwrap();
    let mut c = RunConfig::new(spec, AlgoConfig::a2c(Algo::A2cDelayed));
    c.n_envs = 16;
    c.n_actors = n_actors;
    c.seed = seed;
    c.stop = StopCond::updates(6);
    c
}

fn have_artifacts() -> bool {
    hts_rl::coordinator::common::default_artifacts_dir()
        .join("manifest.json")
        .exists()
}

#[test]
fn hts_identical_across_actor_counts() {
    if !have_artifacts() {
        return;
    }
    let r1 = run(Method::Hts, &cfg(1, 7)).unwrap();
    let r3 = run(Method::Hts, &cfg(3, 7)).unwrap();
    assert_eq!(
        r1.signature, r3.signature,
        "trajectories must be identical for any actor count"
    );
    assert_eq!(r1.steps, r3.steps);
}

/// Paper Tab. 4: the run signature for a fixed seed must be bit-identical
/// for n_actors ∈ {1, 2, 4} — the striped-shard gather must not let the
/// actor count (or executor scheduling) leak into the `[T, B]` batch the
/// learner trains on.
#[test]
fn hts_tab4_signature_invariant_actor_sweep() {
    if !have_artifacts() {
        return;
    }
    let runs: Vec<_> = [1usize, 2, 4]
        .iter()
        .map(|&n| (n, run(Method::Hts, &cfg(n, 13)).unwrap()))
        .collect();
    let (_, base) = &runs[0];
    for (n, r) in &runs[1..] {
        assert_eq!(
            base.signature, r.signature,
            "signature diverged at n_actors={n}"
        );
        assert_eq!(base.steps, r.steps, "step count diverged at {n}");
        assert_eq!(base.updates, r.updates, "updates diverged at {n}");
    }
}

/// ISSUE 2 tentpole obligation: the run signature must be bit-identical
/// across every (n_threads, K) factorization of n_envs — pooling K
/// replicas onto one executor thread reorders *scheduling*, never
/// *trajectories* — and simultaneously across actor counts. n_envs = 8,
/// K ∈ {1, 2, 4, 8} (8 threads × 1 replica down to 1 thread × 8).
#[test]
fn hts_tab4_signature_invariant_replica_pool_sweep() {
    if !have_artifacts() {
        return;
    }
    let pool_cfg = |n_actors: usize, k: usize| {
        let mut c = cfg(n_actors, 19);
        c.n_envs = 8;
        c.replicas_per_executor = k;
        c
    };
    let base = run(Method::Hts, &pool_cfg(1, 1)).unwrap();
    for (n_actors, k) in
        [(1usize, 2usize), (1, 4), (1, 8), (2, 2), (3, 4), (2, 8)]
    {
        let r = run(Method::Hts, &pool_cfg(n_actors, k)).unwrap();
        assert_eq!(
            base.signature, r.signature,
            "signature diverged at n_actors={n_actors} K={k}"
        );
        assert_eq!(base.steps, r.steps, "steps diverged at K={k}");
        assert_eq!(base.updates, r.updates, "updates diverged at K={k}");
    }
}

/// ISSUE 4 acceptance (artifact-gated end-to-end leg; the artifact-free
/// pinned leg lives in `pool.rs`): the new multi-agent gridworld family
/// runs through all three drivers, with HTS bit-identical for every
/// (n_threads, K) factorization and actor count, and the sync baseline
/// bit-identical across repeats.
#[test]
fn team_gridworld_all_drivers_and_pool_sweep() {
    if !have_artifacts() {
        return;
    }
    let team_cfg = |n_actors: usize, k: usize| {
        let spec = EnvSpec::by_name("gridworld_team/gather?slip=0.1")
            .unwrap()
            .with_agents(2)
            .unwrap();
        let mut c = RunConfig::new(spec, AlgoConfig::a2c(Algo::A2cDelayed));
        c.n_envs = 8;
        c.n_actors = n_actors;
        c.seed = 23;
        c.replicas_per_executor = k;
        c.stop = StopCond::updates(4);
        c
    };
    let base = run(Method::Hts, &team_cfg(1, 1)).unwrap();
    for (n_actors, k) in [(1usize, 2usize), (2, 4), (3, 8)] {
        let r = run(Method::Hts, &team_cfg(n_actors, k)).unwrap();
        assert_eq!(
            base.signature, r.signature,
            "team sig diverged at n_actors={n_actors} K={k}"
        );
        assert_eq!(base.steps, r.steps);
    }
    let s1 = run(Method::Sync, &team_cfg(1, 1)).unwrap();
    let s2 = run(Method::Sync, &team_cfg(1, 1)).unwrap();
    assert_eq!(s1.signature, s2.signature, "sync team determinism");
    assert!(s1.steps > 0);
    let mut async_cfg = team_cfg(2, 1);
    async_cfg.algo = AlgoConfig::a2c(Algo::Vtrace);
    let a = run(Method::Async, &async_cfg).unwrap();
    assert!(a.steps > 0 && a.final_loss.is_finite(), "async team run");
}

#[test]
fn hts_identical_across_repeated_runs() {
    if !have_artifacts() {
        return;
    }
    let a = run(Method::Hts, &cfg(2, 11)).unwrap();
    let b = run(Method::Hts, &cfg(2, 11)).unwrap();
    assert_eq!(a.signature, b.signature);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.updates, b.updates);
}

#[test]
fn hts_seed_changes_trajectories() {
    if !have_artifacts() {
        return;
    }
    let a = run(Method::Hts, &cfg(2, 1)).unwrap();
    let b = run(Method::Hts, &cfg(2, 2)).unwrap();
    assert_ne!(a.signature, b.signature);
}

#[test]
fn sync_baseline_is_also_deterministic() {
    // A2C's determinism is a known property (paper §2) — our baseline
    // must preserve it for fair comparisons.
    if !have_artifacts() {
        return;
    }
    let a = run(Method::Sync, &cfg(1, 5)).unwrap();
    let b = run(Method::Sync, &cfg(1, 5)).unwrap();
    assert_eq!(a.signature, b.signature);
}
