//! Campaign-engine integration tests — artifact-free, pure L3+ (the
//! ISSUE 5 acceptance surface).
//!
//! Jobs run the *real* stand-in fleet
//! (`executor::harness::run_standin_job`): real envs, real replica
//! pools, real mailboxes/swap, deterministic `seed % act_dim` policy —
//! so per-job trajectory signatures are real trajectory signatures.
//!
//! The tentpole obligations:
//! * **jobs-invariance** — per-job signatures and every rendered report
//!   byte are identical across `--jobs ∈ {1, 4}`, pinned to constants
//!   from the independent Python transliteration
//!   (`python/tools/pin_signatures.py`, campaign block).
//! * **resume** — a campaign killed mid-way (including a torn final
//!   journal line) resumes, skips completed jobs, and produces a
//!   byte-identical report.
//! * **worker-count-invariance** (PR 8, DESIGN.md §13) — the same
//!   campaign across a distributed worker fleet (shared-directory
//!   claims, per-worker journals, coordinator merge), including a
//!   fleet with an injected worker death and re-issue, renders all
//!   four report artifacts byte-identical to the single-host run,
//!   pinned to the same Python constants plus the 2-worker split
//!   block.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use hts_rl::campaign::dist::{
    coordinate, run_worker, ClaimSource, CoordinatorOpts, FileClaims,
    SharedDir, WorkerOpts,
};
use hts_rl::campaign::{
    self, CampaignConfig, CampaignMeta, CampaignPlan, Job, Journal,
};
use hts_rl::coordinator::{Method, RunConfig, StopCond};
use hts_rl::executor::harness::run_standin_job;
use hts_rl::metrics::TrainReport;

/// The quick `gridworld_team` campaign: first two suite specs (gather,
/// agents=2, slip 0 / 0.15) × hts × 2 seeds, campaign seed 42 — the
/// grid the Python pins are generated for.
fn team_cfg() -> CampaignConfig {
    let mut cfg = CampaignConfig::new("gridworld_team");
    cfg.methods = vec![Method::Hts];
    cfg.seeds = 2;
    cfg.campaign_seed = 42;
    cfg.max_specs = Some(2);
    cfg.n_envs = 8;
    cfg.n_actors = 2;
    cfg.stop = StopCond::updates(4);
    cfg.eval_every = 2;
    cfg.eval_episodes = 5;
    cfg.rt_targets = vec![0.5];
    cfg
}

fn standin(_job: &Job, rc: &RunConfig) -> anyhow::Result<TrainReport> {
    run_standin_job(rc)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("htsrl_campaign_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// ISSUE 5 acceptance: the same campaign at `--jobs 1` and `--jobs 4`
/// yields identical per-job trajectory signatures — pinned to the
/// independent transliteration — and byte-identical rendered reports.
#[test]
fn campaign_jobs_invariance_pinned() {
    // python/tools/pin_signatures.py (campaign block): derived per-job
    // seeds and the stand-in fleet's trajectory signatures, plan order
    const PINNED_JOB_SEEDS: [u64; 4] = [
        0x997a8d5250c1bbcb,
        0xbb8643a14f3974c8,
        0xde82f220da554965,
        0x02b4fcc483598ecf,
    ];
    const PINNED_JOB_SIGNATURES: [u64; 4] = [
        0x535763c191a25960,
        0x94e5566e3f245123,
        0xcef405bf29c4d4ab,
        0x4760bb44b684645a,
    ];

    let cfg1 = team_cfg();
    let plan1 = campaign::expand(&cfg1).unwrap();
    assert_eq!(plan1.jobs.len(), 4);
    assert_eq!(
        plan1.jobs[0].id,
        "gridworld_team/gather?slip=0,agents=2|hts|s0"
    );
    assert_eq!(
        plan1.jobs[2].id,
        "gridworld_team/gather?slip=0.15,agents=2|hts|s0"
    );
    let seeds: Vec<u64> = plan1.jobs.iter().map(|j| j.seed).collect();
    assert_eq!(seeds, PINNED_JOB_SEEDS, "seed derivation regressed");

    let out1 =
        campaign::run_campaign(&cfg1, &plan1, &standin, None, &[], &[], None)
            .unwrap();
    let sigs: Vec<u64> = out1
        .records
        .iter()
        .map(|r| r.as_ref().unwrap().signature)
        .collect();
    assert_eq!(
        sigs,
        PINNED_JOB_SIGNATURES.to_vec(),
        "per-job trajectory signatures regressed"
    );

    let mut cfg4 = team_cfg();
    cfg4.jobs = 4;
    let plan4 = campaign::expand(&cfg4).unwrap();
    let out4 =
        campaign::run_campaign(&cfg4, &plan4, &standin, None, &[], &[], None)
            .unwrap();
    assert_eq!(
        out1.records, out4.records,
        "job records diverged across --jobs"
    );
    // ISSUE 6: the same campaign through a shared cross-job actor fleet
    // (one StandInHub fleet per model config, per-job mailbox-column
    // windows, concurrent workers) must reproduce the pinned per-job
    // signatures exactly — sharing a fleet shifts columns, never seeds
    // or draw order.
    let mut cfg_hub = team_cfg();
    cfg_hub.jobs = 4;
    let plan_hub = campaign::expand(&cfg_hub).unwrap();
    let hub_jobs: Vec<(String, RunConfig)> = plan_hub
        .jobs
        .iter()
        .map(|j| (j.id.clone(), campaign::job_run_config(&cfg_hub, j)))
        .collect();
    let hub = hts_rl::executor::harness::StandInHub::new(&hub_jobs, 2)
        .unwrap();
    let hub_runner = campaign::standin_hub_runner(&hub);
    let out_hub = campaign::run_campaign(
        &cfg_hub, &plan_hub, &hub_runner, None, &[], &[], None,
    )
    .unwrap();
    hub.finish();
    let hub_sigs: Vec<u64> = out_hub
        .records
        .iter()
        .map(|r| r.as_ref().unwrap().signature)
        .collect();
    assert_eq!(
        hub_sigs,
        PINNED_JOB_SIGNATURES.to_vec(),
        "shared-fleet per-job signatures diverged from private fleets"
    );
    assert_eq!(
        out1.records, out_hub.records,
        "job records diverged between private and shared fleets"
    );

    let rep1 = campaign::render(&cfg1, &plan1, &out1);
    let rep4 = campaign::render(&cfg4, &plan4, &out4);
    // comma-bearing spec strings must land as one quoted CSV cell
    assert!(
        rep1.jobs_csv
            .contains("\"gridworld_team/gather?slip=0,agents=2\""),
        "{}",
        rep1.jobs_csv
    );
    assert_eq!(rep1.jobs_csv, rep4.jobs_csv);
    assert_eq!(rep1.summary_csv, rep4.summary_csv);
    // the markdown header names the worker count's *plan* stats only —
    // it must also be byte-identical
    assert_eq!(rep1.markdown, rep4.markdown);
}

/// The invariance above is not a constant-output artifact: a different
/// campaign seed moves every per-job seed and signature.
#[test]
fn campaign_seed_sensitivity() {
    let cfg_a = team_cfg();
    let mut cfg_b = team_cfg();
    cfg_b.campaign_seed = 43;
    let plan_a = campaign::expand(&cfg_a).unwrap();
    let plan_b = campaign::expand(&cfg_b).unwrap();
    let out_a =
        campaign::run_campaign(&cfg_a, &plan_a, &standin, None, &[], &[], None)
            .unwrap();
    let out_b =
        campaign::run_campaign(&cfg_b, &plan_b, &standin, None, &[], &[], None)
            .unwrap();
    for (a, b) in out_a.records.iter().zip(&out_b.records) {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_ne!(a.seed, b.seed);
        assert_ne!(a.signature, b.signature);
    }
}

/// ISSUE 5 acceptance: a campaign killed mid-way — with a torn final
/// journal line — resumes, skips completed jobs, and produces a report
/// byte-identical to an uninterrupted run.
#[test]
fn campaign_resume_matches_uninterrupted_run() {
    let dir = tmp_dir("resume");
    let cfg = team_cfg();
    let plan = campaign::expand(&cfg).unwrap();
    let meta = CampaignMeta {
        suite: cfg.suite.clone(),
        campaign_seed: cfg.campaign_seed,
        n_jobs: plan.jobs.len(),
        config: cfg.fingerprint(),
        worker: None,
    };

    // reference: one uninterrupted run
    let out_ref =
        campaign::run_campaign(&cfg, &plan, &standin, None, &[], &[], None)
            .unwrap();
    let rep_ref = campaign::render(&cfg, &plan, &out_ref);

    // crashed run: the 3rd job dies after two jobs were journaled
    let jpath = dir.join("campaign.jsonl");
    let journal = Journal::create(&jpath, &meta).unwrap();
    let fail_id = plan.jobs[2].id.clone();
    let dying = |job: &Job, rc: &RunConfig| {
        if job.id == fail_id {
            anyhow::bail!("injected crash");
        }
        run_standin_job(rc)
    };
    let err = campaign::run_campaign(
        &cfg,
        &plan,
        &dying,
        Some(&journal),
        &[],
        &[],
        None,
    );
    assert!(err.is_err(), "the injected crash must surface");
    drop(journal);
    // ... and the crash tore the final journal line mid-write
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&jpath)
            .unwrap();
        write!(f, "{{\"v\":1,\"id\":\"gridworld_team/gather?sl").unwrap();
    }

    // resume: replay the journal, run only what's missing
    let (journal2, done, done_tel) = Journal::resume(&jpath, &meta).unwrap();
    assert_eq!(done.len(), 2, "two clean records, torn line dropped");
    let ran = AtomicUsize::new(0);
    let counting = |_job: &Job, rc: &RunConfig| {
        ran.fetch_add(1, Ordering::Relaxed);
        run_standin_job(rc)
    };
    let out2 = campaign::run_campaign(
        &cfg,
        &plan,
        &counting,
        Some(&journal2),
        &done,
        &done_tel,
        None,
    )
    .unwrap();
    assert_eq!(
        ran.load(Ordering::Relaxed),
        plan.jobs.len() - done.len(),
        "resume must skip journaled jobs"
    );
    assert_eq!(out2.resumed, done.len());

    let rep2 = campaign::render(&cfg, &plan, &out2);
    assert_eq!(rep_ref.jobs_csv, rep2.jobs_csv);
    assert_eq!(rep_ref.summary_csv, rep2.summary_csv);
    assert_eq!(rep_ref.markdown, rep2.markdown);

    // a second resume of the now-complete journal runs nothing at all
    let (journal3, done3, done_tel3) = Journal::resume(&jpath, &meta).unwrap();
    assert_eq!(done3.len(), plan.jobs.len());
    let ran3 = AtomicUsize::new(0);
    let counting3 = |_job: &Job, rc: &RunConfig| {
        ran3.fetch_add(1, Ordering::Relaxed);
        run_standin_job(rc)
    };
    let out3 = campaign::run_campaign(
        &cfg,
        &plan,
        &counting3,
        Some(&journal3),
        &done3,
        &done_tel3,
        None,
    )
    .unwrap();
    assert_eq!(ran3.load(Ordering::Relaxed), 0);
    assert_eq!(out3.resumed, plan.jobs.len());
    let rep3 = campaign::render(&cfg, &plan, &out3);
    assert_eq!(rep_ref.jobs_csv, rep3.jobs_csv);

    // a changed run configuration (same suite, seed, and grid size)
    // must not reuse this journal — the config fingerprint differs
    let mut cfg2 = team_cfg();
    cfg2.stop = StopCond::updates(8);
    let meta2 = CampaignMeta {
        suite: cfg2.suite.clone(),
        campaign_seed: cfg2.campaign_seed,
        n_jobs: plan.jobs.len(),
        config: cfg2.fingerprint(),
        worker: None,
    };
    assert!(Journal::resume(&jpath, &meta2).is_err());

    let _ = std::fs::remove_dir_all(&dir);
}

/// PR 7 tentpole acceptance at the campaign layer: switching telemetry
/// on changes none of the three core artifacts — jobs CSV, summary CSV,
/// markdown are byte-identical with and without `--telemetry` — while
/// the run gains a fourth, separate utilization artifact whose numbers
/// come from real executor/actor/buffer counters.
#[test]
fn campaign_telemetry_is_invisible_to_core_artifacts() {
    let cfg_off = team_cfg();
    let plan_off = campaign::expand(&cfg_off).unwrap();
    let out_off = campaign::run_campaign(
        &cfg_off, &plan_off, &standin, None, &[], &[], None,
    )
    .unwrap();

    let mut cfg_on = team_cfg();
    cfg_on.telemetry = true;
    let plan_on = campaign::expand(&cfg_on).unwrap();
    let out_on = campaign::run_campaign(
        &cfg_on, &plan_on, &standin, None, &[], &[], None,
    )
    .unwrap();

    // telemetry is not part of the plan fingerprint: same jobs, seeds
    assert_eq!(cfg_off.fingerprint(), cfg_on.fingerprint());
    assert_eq!(
        out_off.records, out_on.records,
        "telemetry moved a job record"
    );

    let rep_off = campaign::render(&cfg_off, &plan_off, &out_off);
    let rep_on = campaign::render(&cfg_on, &plan_on, &out_on);
    assert_eq!(rep_off.jobs_csv, rep_on.jobs_csv);
    assert_eq!(rep_off.summary_csv, rep_on.summary_csv);
    assert_eq!(rep_off.markdown, rep_on.markdown);

    assert!(rep_off.telemetry_csv.is_none(), "no telemetry, no artifact");
    assert!(
        rep_off.telemetry_md.is_none(),
        "no telemetry, no markdown summary"
    );
    assert!(
        out_on.telemetry.iter().all(Option::is_some),
        "every instrumented job must attach a telemetry report"
    );
    let tel_md = rep_on.telemetry_md.as_deref().expect("telemetry summary");
    assert!(tel_md.starts_with("# Campaign "), "{tel_md}");
    let tel_csv = rep_on.telemetry_csv.expect("telemetry artifact");
    assert!(tel_csv.starts_with("spec,method,jobs,steps_total,"));
    // one merged row per (spec, method) group + header
    assert_eq!(tel_csv.lines().count(), 1 + 2);
    // real counters flowed through: each job stepped a positive number
    // of envs
    for t in out_on.telemetry.iter().flatten() {
        assert!(t.report.counter("steps_total") > 0);
    }
}

/// The counter *merge* is jobs-invariant and survives a kill/resume
/// cycle: with a runner whose telemetry is a pure function of the job,
/// the plan-indexed telemetry vector — and the rendered utilization
/// artifact — are identical across `--jobs {1, 4}` and across a journal
/// round-trip. (Real executor telemetry is timing-dependent by nature;
/// the *plumbing* must still be deterministic.)
#[test]
fn campaign_telemetry_merge_jobs_invariant_and_resumes() {
    use hts_rl::telemetry::{Counter, TelemetryScope};

    let synthetic = |job: &Job, rc: &RunConfig| -> anyhow::Result<TrainReport> {
        let mut scope = TelemetryScope::new(true);
        scope.add(Counter::StepsTotal, (job.seed & 0xffff) + 1);
        scope.add(Counter::SoloSteps, (job.seed & 0xffff) + 1);
        scope.add(Counter::GrabBatches, 3);
        scope.add(Counter::GrabColumns, 12);
        Ok(TrainReport {
            steps: rc.stop.max_updates.unwrap_or(1),
            wall_s: 1.0,
            signature: job.seed,
            telemetry: Some(scope.report()),
            ..TrainReport::default()
        })
    };

    let cfg1 = team_cfg();
    let plan = campaign::expand(&cfg1).unwrap();
    let out1 = campaign::run_campaign(
        &cfg1, &plan, &synthetic, None, &[], &[], None,
    )
    .unwrap();
    let mut cfg4 = team_cfg();
    cfg4.jobs = 4;
    let out4 = campaign::run_campaign(
        &cfg4, &plan, &synthetic, None, &[], &[], None,
    )
    .unwrap();
    assert_eq!(
        out1.telemetry, out4.telemetry,
        "telemetry vector diverged across --jobs"
    );
    let rep1 = campaign::render(&cfg1, &plan, &out1);
    let rep4 = campaign::render(&cfg4, &plan, &out4);
    assert_eq!(rep1.telemetry_csv, rep4.telemetry_csv);

    // journal round-trip: telemetry lines replay and re-pair by job id
    let dir = tmp_dir("tel_resume");
    let jpath = dir.join("campaign.jsonl");
    let meta = CampaignMeta {
        suite: cfg1.suite.clone(),
        campaign_seed: cfg1.campaign_seed,
        n_jobs: plan.jobs.len(),
        config: cfg1.fingerprint(),
        worker: None,
    };
    let journal = Journal::create(&jpath, &meta).unwrap();
    journal.enable_telemetry();
    let out_j = campaign::run_campaign(
        &cfg1, &plan, &synthetic, Some(&journal), &[], &[], None,
    )
    .unwrap();
    drop(journal);

    let (journal2, done, done_tel) = Journal::resume(&jpath, &meta).unwrap();
    assert_eq!(done.len(), plan.jobs.len());
    assert_eq!(done_tel.len(), plan.jobs.len(), "telemetry lines replayed");
    let ran = AtomicUsize::new(0);
    let counting = |job: &Job, rc: &RunConfig| {
        ran.fetch_add(1, Ordering::Relaxed);
        synthetic(job, rc)
    };
    let out_r = campaign::run_campaign(
        &cfg1,
        &plan,
        &counting,
        Some(&journal2),
        &done,
        &done_tel,
        None,
    )
    .unwrap();
    assert_eq!(ran.load(Ordering::Relaxed), 0, "everything was journaled");
    assert_eq!(
        out_j.telemetry, out_r.telemetry,
        "resumed telemetry diverged from the original run"
    );
    assert_eq!(
        campaign::render(&cfg1, &plan, &out_j).telemetry_csv,
        campaign::render(&cfg1, &plan, &out_r).telemetry_csv
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The per-job curve CSVs flow through the shared
/// `metrics::report::write_curve_csv` helper — the same writer
/// `hts-rl train --out` uses — named by sanitized spec and seed index.
#[test]
fn campaign_writes_per_job_curves_via_shared_helper() {
    let dir = tmp_dir("curves");
    // catch episodes complete every 9 steps, so every stand-in job is
    // guaranteed an episode log (team episodes rarely finish inside the
    // tiny 20-step budget)
    let mut cfg = CampaignConfig::new("catch_wind");
    cfg.methods = vec![Method::Hts];
    cfg.seeds = 1;
    cfg.campaign_seed = 7;
    cfg.max_specs = Some(2);
    cfg.n_envs = 4;
    cfg.n_actors = 1;
    cfg.stop = StopCond::updates(4);
    cfg.eval_every = 2;
    cfg.eval_episodes = 3;
    let plan = campaign::expand(&cfg).unwrap();
    let out = campaign::run_campaign(
        &cfg,
        &plan,
        &standin,
        None,
        &[],
        &[],
        Some(&dir),
    )
    .unwrap();
    for (job, rec) in plan.jobs.iter().zip(&out.records) {
        let rec = rec.as_ref().unwrap();
        let path = dir.join(format!(
            "curve_hts_{}_s{}.csv",
            hts_rl::metrics::report::sanitize_spec_name(&rec.spec),
            job.seed_index
        ));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(text.starts_with("steps,wall_s,reward_ma100\n"));
        assert!(text.lines().count() >= 2, "curve has data rows");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --- distributed campaigns (PR 8, DESIGN.md §13) ------------------------

/// The campaign identity every fleet participant presents: `worker:
/// None` — workers stamp their own id into their journal copy.
fn shared_meta(cfg: &CampaignConfig, plan: &CampaignPlan) -> CampaignMeta {
    CampaignMeta {
        suite: cfg.suite.clone(),
        campaign_seed: cfg.campaign_seed,
        n_jobs: plan.jobs.len(),
        config: cfg.fingerprint(),
        worker: None,
    }
}

/// Stand-in runner with *deterministic* telemetry: the real stand-in
/// fleet's counters are timing-dependent (parks, poll misses), so the
/// byte-identity tests attach a synthetic report that is a pure
/// function of the job — the merge/render plumbing under test cannot
/// tell the difference.
fn standin_tel(job: &Job, rc: &RunConfig) -> anyhow::Result<TrainReport> {
    use hts_rl::telemetry::{Counter, TelemetryScope};
    let mut quiet = rc.clone();
    quiet.telemetry = false;
    let mut r = run_standin_job(&quiet)?;
    let mut scope = TelemetryScope::new(true);
    scope.add(Counter::StepsTotal, (job.seed & 0xffff) + 1);
    scope.add(Counter::SoloSteps, (job.seed & 0xffff) + 1);
    scope.add(Counter::GrabBatches, 3);
    scope.add(Counter::GrabColumns, 12);
    r.telemetry = Some(scope.report());
    Ok(r)
}

fn assert_same_artifacts(
    a: &campaign::CampaignReport,
    b: &campaign::CampaignReport,
    what: &str,
) {
    assert_eq!(a.jobs_csv, b.jobs_csv, "{what}: jobs CSV diverged");
    assert_eq!(a.summary_csv, b.summary_csv, "{what}: summary CSV diverged");
    assert_eq!(a.markdown, b.markdown, "{what}: markdown diverged");
    assert_eq!(
        a.telemetry_csv, b.telemetry_csv,
        "{what}: telemetry CSV diverged"
    );
    assert_eq!(
        a.telemetry_md, b.telemetry_md,
        "{what}: telemetry markdown diverged"
    );
}

/// PR 8 acceptance: all four report artifacts are byte-identical
/// across single-host `--jobs {1, 4}` and a concurrent 2-worker
/// distributed run merged by the coordinator.
#[test]
fn dist_worker_count_invariance_all_artifacts() {
    let mut cfg = team_cfg();
    cfg.telemetry = true;
    let plan = campaign::expand(&cfg).unwrap();
    let out1 = campaign::run_campaign(
        &cfg, &plan, &standin_tel, None, &[], &[], None,
    )
    .unwrap();
    let rep1 = campaign::render(&cfg, &plan, &out1);
    assert!(rep1.telemetry_csv.is_some(), "the fourth artifact exists");

    let mut cfg4 = team_cfg();
    cfg4.telemetry = true;
    cfg4.jobs = 4;
    let out4 = campaign::run_campaign(
        &cfg4, &plan, &standin_tel, None, &[], &[], None,
    )
    .unwrap();
    assert_same_artifacts(
        &rep1,
        &campaign::render(&cfg4, &plan, &out4),
        "--jobs 4",
    );

    // the same campaign as a 2-worker fleet racing over one shared dir
    let dir = tmp_dir("dist_wc");
    let shared = SharedDir::new(&dir);
    let meta = shared_meta(&cfg, &plan);
    std::thread::scope(|s| {
        for id in ["a", "b"] {
            let (shared, meta, cfg, plan) = (&shared, &meta, &cfg, &plan);
            s.spawn(move || {
                let mut o = WorkerOpts::new(id);
                o.lease_ttl_s = 10.0;
                run_worker(cfg, plan, &standin_tel, meta, shared, &o, None)
                    .unwrap();
            });
        }
    });
    let copts = CoordinatorOpts {
        lease_ttl_s: 10.0,
        poll_s: 0.02,
        run_stragglers: true,
    };
    let outd =
        coordinate(&cfg, &plan, &standin_tel, &meta, &shared, &copts, None)
            .unwrap();
    assert_eq!(
        out1.records, outd.records,
        "merged records diverged from single-host"
    );
    assert_same_artifacts(
        &rep1,
        &campaign::render(&cfg, &plan, &outd),
        "2-worker fleet",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR 8 acceptance, fault half: a worker killed mid-claim (lease
/// abandoned, claim held, record never journaled) is detected by TTL
/// expiry; its job is re-issued and the final artifacts are
/// byte-identical to the uninterrupted run.
#[test]
fn dist_dead_worker_reissue_matches_uninterrupted() {
    let mut cfg = team_cfg();
    cfg.telemetry = true;
    let plan = campaign::expand(&cfg).unwrap();
    let out_ref = campaign::run_campaign(
        &cfg, &plan, &standin_tel, None, &[], &[], None,
    )
    .unwrap();
    let rep_ref = campaign::render(&cfg, &plan, &out_ref);

    let dir = tmp_dir("dist_dead");
    let shared = SharedDir::new(&dir);
    let meta = shared_meta(&cfg, &plan);
    // worker a runs one job, then dies holding its second claim
    let mut oa = WorkerOpts::new("a");
    oa.lease_ttl_s = 0.2;
    oa.heartbeat_s = 0.05;
    oa.die_after_jobs = Some(1);
    let sa =
        run_worker(&cfg, &plan, &standin_tel, &meta, &shared, &oa, None)
            .unwrap();
    assert!(sa.died, "the fault hook must fire");
    assert_eq!(sa.ran, 1);
    // worker b drains what it can — the dead worker's claim is not its
    // to touch, so exactly two jobs remain for it
    let mut ob = WorkerOpts::new("b");
    ob.lease_ttl_s = 0.2;
    ob.heartbeat_s = 0.05;
    let sb =
        run_worker(&cfg, &plan, &standin_tel, &meta, &shared, &ob, None)
            .unwrap();
    assert_eq!(sb.ran, 2, "peers never steal a held claim");
    // the coordinator waits out the TTL, expires a's lease, re-issues
    // the orphaned job, and (nobody else alive) runs it itself
    let copts = CoordinatorOpts {
        lease_ttl_s: 0.2,
        poll_s: 0.02,
        run_stragglers: true,
    };
    let outd =
        coordinate(&cfg, &plan, &standin_tel, &meta, &shared, &copts, None)
            .unwrap();
    assert_eq!(
        out_ref.records, outd.records,
        "re-issued job produced different bytes"
    );
    assert_same_artifacts(
        &rep_ref,
        &campaign::render(&cfg, &plan, &outd),
        "dead-worker re-issue",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the claim protocol under contention — N in-process
/// claimer threads over one shared directory; every plan index is
/// claimed by exactly one claimer, none twice, none dropped.
#[test]
fn dist_concurrent_claims_each_index_exactly_once() {
    const N: usize = 120;
    let dir = tmp_dir("dist_claims");
    let shared = SharedDir::new(&dir);
    shared.ensure_layout().unwrap();
    let per: Vec<Vec<usize>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let shared = &shared;
                s.spawn(move || {
                    let src = FileClaims::new(shared, format!("w{t}"), N);
                    let mut got = Vec::new();
                    while let Some(i) = src.claim_next().unwrap() {
                        got.push(i);
                    }
                    got
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut all: Vec<usize> = per.concat();
    all.sort_unstable();
    assert_eq!(
        all,
        (0..N).collect::<Vec<_>>(),
        "every index claimed exactly once across 8 racing claimers"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: crash debris — zero-length and half-written claim files,
/// a claim whose owner has only a zero-length (torn) lease, a stranded
/// `.tmp` from an interrupted atomic rename — recovers cleanly, and
/// the recovered campaign's artifacts match the uninterrupted run
/// (the PR 5 torn-journal posture applied to the claim protocol).
#[test]
fn dist_torn_claim_and_lease_artifacts_recover() {
    let cfg = team_cfg();
    let plan = campaign::expand(&cfg).unwrap();
    let out_ref =
        campaign::run_campaign(&cfg, &plan, &standin, None, &[], &[], None)
            .unwrap();
    let rep_ref = campaign::render(&cfg, &plan, &out_ref);

    let dir = tmp_dir("dist_torn");
    let shared = SharedDir::new(&dir);
    shared.ensure_layout().unwrap();
    std::fs::write(shared.claim_path(0), "").unwrap();
    std::fs::write(shared.claim_path(1), "{\"v\":1,\"ind").unwrap();
    assert!(shared.try_claim(2, "ghost").unwrap());
    std::fs::write(shared.lease_path("ghost"), "").unwrap();
    std::fs::write(dir.join("leases").join("ghost.lease.x.tmp"), "junk")
        .unwrap();
    // age the debris past the TTL so expiry can fire
    std::thread::sleep(Duration::from_millis(120));
    let meta = shared_meta(&cfg, &plan);
    let copts = CoordinatorOpts {
        lease_ttl_s: 0.05,
        poll_s: 0.01,
        run_stragglers: true,
    };
    let outd = coordinate(&cfg, &plan, &standin, &meta, &shared, &copts, None)
        .unwrap();
    assert_eq!(out_ref.records, outd.records);
    assert_same_artifacts(
        &rep_ref,
        &campaign::render(&cfg, &plan, &outd),
        "torn-artifact recovery",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the `--resume` fingerprint check covers the fleet — a
/// worker (old id or new) arriving under a changed plan/budget dies at
/// the campaign marker; its own journal header rejects the changed
/// meta; and the coordinator refuses a journal filed under the wrong
/// worker name.
#[test]
fn dist_worker_rejects_changed_campaign_config() {
    let cfg = team_cfg();
    let plan = campaign::expand(&cfg).unwrap();
    let dir = tmp_dir("dist_fpr");
    let shared = SharedDir::new(&dir);
    let meta = shared_meta(&cfg, &plan);
    let mut oa = WorkerOpts::new("a");
    oa.max_jobs = Some(1);
    run_worker(&cfg, &plan, &standin, &meta, &shared, &oa, None).unwrap();

    // same suite/seed/grid, different per-job budget: new fingerprint
    let mut cfg2 = team_cfg();
    cfg2.stop = StopCond::updates(8);
    let plan2 = campaign::expand(&cfg2).unwrap();
    let meta2 = shared_meta(&cfg2, &plan2);
    assert_ne!(meta.config, meta2.config);
    for id in ["a", "c"] {
        let err = run_worker(
            &cfg2,
            &plan2,
            &standin,
            &meta2,
            &shared,
            &WorkerOpts::new(id),
            None,
        );
        assert!(
            err.is_err(),
            "worker '{id}' must not join a changed campaign"
        );
    }
    // the per-worker journal header enforces the same fingerprint
    let my_meta2 =
        CampaignMeta { worker: Some("a".into()), ..meta2.clone() };
    assert!(Journal::resume(&shared.journal_path("a"), &my_meta2).is_err());
    // a journal copied under another worker's name fails the merge
    std::fs::copy(shared.journal_path("a"), shared.journal_path("b"))
        .unwrap();
    let copts = CoordinatorOpts {
        lease_ttl_s: 1.0,
        poll_s: 0.01,
        run_stragglers: true,
    };
    assert!(
        coordinate(&cfg, &plan, &standin, &meta, &shared, &copts, None)
            .is_err(),
        "a journal whose header names a different worker must not merge"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: the 2-worker split of the quick `gridworld_team`
/// campaign, pinned against the independent Python transliteration
/// (`pin_signatures.py::emit_campaign_dist`): worker a's journal holds
/// plan indices 0–1, worker b's 2–3, and the merged outcome reproduces
/// the full single-host pin block.
#[test]
fn dist_two_worker_split_pins() {
    // python/tools/pin_signatures.py (dist campaign block)
    const DIST_WORKER_A_SEEDS: [u64; 2] =
        [0x997a8d5250c1bbcb, 0xbb8643a14f3974c8];
    const DIST_WORKER_A_SIGNATURES: [u64; 2] =
        [0x535763c191a25960, 0x94e5566e3f245123];
    const DIST_WORKER_B_SEEDS: [u64; 2] =
        [0xde82f220da554965, 0x02b4fcc483598ecf];
    const DIST_WORKER_B_SIGNATURES: [u64; 2] =
        [0xcef405bf29c4d4ab, 0x4760bb44b684645a];

    let cfg = team_cfg();
    let plan = campaign::expand(&cfg).unwrap();
    let dir = tmp_dir("dist_pins");
    let shared = SharedDir::new(&dir);
    let meta = shared_meta(&cfg, &plan);
    // worker a claims indices 0 and 1 (sequential scan + --max-jobs 2),
    // worker b the rest
    let mut oa = WorkerOpts::new("a");
    oa.max_jobs = Some(2);
    let sa =
        run_worker(&cfg, &plan, &standin, &meta, &shared, &oa, None).unwrap();
    assert_eq!(sa.ran, 2);
    let sb = run_worker(
        &cfg,
        &plan,
        &standin,
        &meta,
        &shared,
        &WorkerOpts::new("b"),
        None,
    )
    .unwrap();
    assert_eq!(sb.ran, 2);
    for (worker, seeds, sigs) in [
        ("a", DIST_WORKER_A_SEEDS, DIST_WORKER_A_SIGNATURES),
        ("b", DIST_WORKER_B_SEEDS, DIST_WORKER_B_SIGNATURES),
    ] {
        let (m, recs, _tels) = hts_rl::campaign::journal::read_records(
            &shared.journal_path(worker),
        )
        .unwrap()
        .expect("journal is complete");
        assert_eq!(m.worker.as_deref(), Some(worker));
        let got_seeds: Vec<u64> = recs.iter().map(|r| r.seed).collect();
        let got_sigs: Vec<u64> = recs.iter().map(|r| r.signature).collect();
        assert_eq!(got_seeds, seeds, "worker '{worker}' seed split");
        assert_eq!(
            got_sigs, sigs,
            "worker '{worker}' signature split regressed"
        );
    }
    let copts = CoordinatorOpts {
        lease_ttl_s: 1.0,
        poll_s: 0.01,
        run_stragglers: true,
    };
    let outd = coordinate(&cfg, &plan, &standin, &meta, &shared, &copts, None)
        .unwrap();
    let merged_sigs: Vec<u64> = outd
        .records
        .iter()
        .map(|r| r.as_ref().unwrap().signature)
        .collect();
    let full: Vec<u64> = DIST_WORKER_A_SIGNATURES
        .iter()
        .chain(&DIST_WORKER_B_SIGNATURES)
        .copied()
        .collect();
    assert_eq!(merged_sigs, full, "merge must reassemble the plan order");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: telemetry stays byte-invisible to the core artifacts in
/// the multi-worker path too — a distributed telemetry campaign and a
/// distributed plain campaign render identical jobs/summary/markdown,
/// and only the former gains the utilization CSV (telemetry lines
/// re-paired with their jobs by id across the merged journals).
#[test]
fn dist_telemetry_invisible_to_core_artifacts() {
    let cfg_off = team_cfg();
    let plan = campaign::expand(&cfg_off).unwrap();
    let mut cfg_on = team_cfg();
    cfg_on.telemetry = true;
    // telemetry is display-only: same fingerprint, same campaign
    assert_eq!(cfg_off.fingerprint(), cfg_on.fingerprint());

    let copts = CoordinatorOpts {
        lease_ttl_s: 1.0,
        poll_s: 0.01,
        run_stragglers: true,
    };
    let mut reports = Vec::new();
    let mut outs = Vec::new();
    for (tag, cfg) in [("off", &cfg_off), ("on", &cfg_on)] {
        let dir = tmp_dir(&format!("dist_tel_{tag}"));
        let shared = SharedDir::new(&dir);
        let meta = shared_meta(cfg, &plan);
        run_worker(
            cfg,
            &plan,
            &standin,
            &meta,
            &shared,
            &WorkerOpts::new("a"),
            None,
        )
        .unwrap();
        let out =
            coordinate(cfg, &plan, &standin, &meta, &shared, &copts, None)
                .unwrap();
        reports.push(campaign::render(cfg, &plan, &out));
        outs.push(out);
        let _ = std::fs::remove_dir_all(&dir);
    }
    let (rep_off, rep_on) = (&reports[0], &reports[1]);
    assert_eq!(rep_off.jobs_csv, rep_on.jobs_csv);
    assert_eq!(rep_off.summary_csv, rep_on.summary_csv);
    assert_eq!(rep_off.markdown, rep_on.markdown);
    assert!(rep_off.telemetry_csv.is_none(), "no telemetry, no artifact");
    assert!(
        rep_on.telemetry_csv.is_some(),
        "the telemetry fleet gains the fourth artifact"
    );
    assert!(
        rep_off.telemetry_md.is_none() && rep_on.telemetry_md.is_some(),
        "the markdown summary mirrors the CSV's presence"
    );
    assert!(
        outs[1].telemetry.iter().all(Option::is_some),
        "every journaled telemetry line re-paired with its job"
    );
}

// --- deterministic event tracing (ISSUE 10, DESIGN.md §15) --------------

/// ISSUE 10 acceptance: `campaign --trace` is byte-invisible to every
/// pinned campaign artifact — same fingerprint, same job records, same
/// rendered reports — while each traced job additionally exports its own
/// Chrome-trace JSON next to the curves, with the scheduler's span track
/// merged in.
#[test]
fn campaign_trace_invisible_to_artifacts_and_exports_per_job() {
    let cfg_off = team_cfg();
    let plan = campaign::expand(&cfg_off).unwrap();
    let out_off = campaign::run_campaign(
        &cfg_off, &plan, &standin, None, &[], &[], None,
    )
    .unwrap();

    let mut cfg_on = team_cfg();
    cfg_on.trace = true;
    // tracing is not part of the plan fingerprint: same jobs, same seeds
    assert_eq!(cfg_off.fingerprint(), cfg_on.fingerprint());
    let dir = tmp_dir("trace_on");
    let out_on = campaign::run_campaign(
        &cfg_on, &plan, &standin, None, &[], &[], Some(&dir),
    )
    .unwrap();
    assert_eq!(
        out_off.records, out_on.records,
        "tracing moved a job record"
    );
    assert_same_artifacts(
        &campaign::render(&cfg_off, &plan, &out_off),
        &campaign::render(&cfg_on, &plan, &out_on),
        "campaign --trace",
    );

    for (job, rec) in plan.jobs.iter().zip(&out_on.records) {
        let rec = rec.as_ref().unwrap();
        let path = dir.join(format!(
            "trace_hts_{}_s{}.json",
            hts_rl::metrics::report::sanitize_spec_name(&rec.spec),
            job.seed_index
        ));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(
            text.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["),
            "not a Chrome-trace export: {}",
            path.display()
        );
        assert!(
            text.contains("\"scheduler-"),
            "scheduler track missing from the per-job trace"
        );
        assert!(
            text.contains("\"executor-"),
            "executor tracks missing from the per-job trace"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 10 flight-recorder satellite: a trace-armed worker that trips
/// the `die_after_jobs` fault dumps its flight ring's tail to
/// `postmortem_<worker>.json` before abandoning its lease; the
/// coordinator still drives the campaign to completion and leaves the
/// dump in place for post-mortem reading.
#[test]
fn dist_dying_trace_worker_leaves_postmortem_dump() {
    let mut cfg = team_cfg();
    cfg.trace = true;
    let plan = campaign::expand(&cfg).unwrap();
    let dir = tmp_dir("dist_postmortem");
    let shared = SharedDir::new(&dir);
    let meta = shared_meta(&cfg, &plan);
    let mut oa = WorkerOpts::new("a");
    oa.lease_ttl_s = 0.2;
    oa.heartbeat_s = 0.05;
    oa.die_after_jobs = Some(1);
    let sa =
        run_worker(&cfg, &plan, &standin, &meta, &shared, &oa, None).unwrap();
    assert!(sa.died, "the fault hook must fire");
    let pm = shared.postmortem_path("a");
    let text = std::fs::read_to_string(&pm).unwrap_or_else(|e| {
        panic!("dying trace worker left no dump at {}: {e}", pm.display())
    });
    assert!(
        text.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["),
        "the dump is a Chrome-trace export too: {text}"
    );
    assert!(text.contains("\"worker-0\""), "worker track named: {text}");
    assert!(text.contains("\"panic\""), "fault instant recorded: {text}");
    assert!(text.contains("\"job_run\""), "claim-loop spans kept: {text}");

    let copts = CoordinatorOpts {
        lease_ttl_s: 0.2,
        poll_s: 0.02,
        run_stragglers: true,
    };
    let outd = coordinate(&cfg, &plan, &standin, &meta, &shared, &copts, None)
        .unwrap();
    assert!(
        outd.records.iter().all(Option::is_some),
        "the fleet still finished every job"
    );
    assert!(
        pm.exists(),
        "the coordinator points at a dump, never removes it"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
