//! Map-iteration hazards: this file's `artifact_` prefix puts it in the
//! artifact zone, so hash-ordered containers fire; ordered ones don't.

use std::collections::HashMap; // <- fires map-iteration (line 4)
use std::collections::HashSet; // <- fires map-iteration (line 5)
use std::collections::BTreeMap;

fn ordered_is_fine() -> BTreeMap<u32, u32> {
    let _quoted = "HashMap in a string never fires";
    // HashMap in a comment never fires
    BTreeMap::new()
}
