//! Lexer gauntlet. Every violation-shaped token below is quoted,
//! commented, or char-escaped and must NOT fire; the real violations
//! are marked with `<- fires` and pinned by line in tests/lint.rs.

/* thread_rng() in a block comment
   /* nested block comment: Instant::now() SystemTime HashMap */
   still inside the outer comment: partial_cmp().unwrap() unsafe
*/

const RAW: &str = r#"thread_rng "quoted" Mutex unsafe {v:016x}"#;
const RAW2: &str = r##"hash-quote "# does not terminate: thread_rng"##;
const PLAIN: &str = "escaped \" quote then thread_rng()";
const BYTES: &[u8] = b"thread_rng bytes \" here";
const RAWB: &[u8] = br#"more thread_rng"#;
const CSTR: &core::ffi::CStr = c"thread_rng as c string";

fn chars_vs_lifetimes<'a>(x: &'a str) -> (char, char, char, u8) {
    let quote = '\'';
    let dquote = '"';
    let newline = '\n';
    let byte = b'"';
    let _lifetime: &'static str = x;
    (quote, dquote, newline, byte)
}

fn real_rng() -> u64 {
    thread_rng().next_u64() // <- fires thread-rng (line 27)
}

fn real_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap() // <- fires nan-cmp (line 31)
}

fn allowed_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    // lint: allow(nan-cmp, fixture: inputs proven NaN-free one line up)
    a.partial_cmp(&b).unwrap() // suppressed by the allow above
}

fn covered() -> u64 {
    // SAFETY: fixture — transmuting between same-width ints is defined.
    unsafe { std::mem::transmute::<i64, u64>(-1) }
}

fn uncovered() -> u64 {
    unsafe { std::mem::transmute::<i64, u64>(-2) } // <- fires unsafe-safety (line 45)
}
