//! Directive misuse: each bad directive is itself a finding (rule id
//! `lint-directive`), so annotations can't rot silently.

// next line fires lint-directive: unknown rule id
// lint: allow(no-such-rule, bogus rule id)
fn noop() {}

// next line fires lint-directive: the allow never suppresses anything
// lint: allow(wall-clock, nothing below ever fires)
fn quiet() {}

// next line fires lint-directive: unparseable hotpath form
// lint: hotpath(middle)
fn still_quiet() {}

// next line fires lint-directive: begin without end
// lint: hotpath(begin, never closed)
fn tail() {}
