//! Unbalanced on purpose: the promoted PR 6 delimiter scanner reports
//! the stray closing brace (and quoted/commented braces don't count).

fn balanced() {
    let _ok = [1, (2), { 3 }];
    let _quoted = "} } } none of these count {";
    // neither do these: } ] )
}
} // <- fires delimiters (line 9)
