//! Lock/alloc discipline: the hotpath rules fire only between markers,
//! and `lint: allow` suppresses exactly one annotated site.

use std::sync::Mutex; // outside the region: no finding

fn cold() -> Vec<u32> {
    Vec::new() // outside the region: no finding
}

// lint: hotpath(begin, fixture hot loop)
fn hot(m: &Mutex<u64>) -> String { // <- fires hotpath-lock (line 11): Mutex
    let g = m.lock().unwrap(); // <- fires hotpath-lock (line 12): .lock(
    let s = format!("{}", *g); // <- fires hotpath-alloc (line 13): format!
    let _v: Vec<u64> = Vec::new(); // <- fires hotpath-alloc (line 14)
    // lint: allow(hotpath-alloc, fixture: growth justified for the test)
    let _w = vec![1u8, 2, 3]; // suppressed by the allow above
    s
}
// lint: hotpath(end)
