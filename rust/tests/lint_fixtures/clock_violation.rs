//! Wall-clock reads OUTSIDE the timekeeping zone: both real-time
//! sources fire.

use std::time::SystemTime; // <- fires wall-clock (line 4): SystemTime

fn stamp() -> u64 {
    let _t = std::time::Instant::now(); // <- fires wall-clock (line 7)
    0
}
