//! Wall-clock reads inside the timekeeping zone (`exempt_` prefix):
//! zero findings — the zone is the sanctioned home of real time.

use std::time::{Instant, SystemTime};

fn now_pair() -> (Instant, SystemTime) {
    (Instant::now(), SystemTime::now())
}
