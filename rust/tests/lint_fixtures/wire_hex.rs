//! Hand-rolled u64 hex: the `wire_` prefix puts this file in the
//! serialization zone, where only util::json::{hex_u64, parse_hex_u64}
//! may touch the wire format.

fn encode(v: u64) -> String {
    format!("0x{:016x}", v) // <- fires hex-u64 (line 6): "016x" literal
}

fn decode(s: &str) -> u64 {
    u64::from_str_radix(s, 16).unwrap() // <- fires hex-u64 (line 10)
}
