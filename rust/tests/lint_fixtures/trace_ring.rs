//! Seeded ISSUE 10 regression: a trace-ring recorder that reads the
//! wall clock directly (only trace/clock.rs may) and allocates inside
//! its marked record hotpath.

struct Ring {
    slots: Vec<u64>,
}

fn origin_ns() -> u64 {
    let _t = std::time::Instant::now(); // <- fires wall-clock (line 10)
    0
}

// lint: hotpath(begin, fixture trace record path)
fn record(r: &mut Ring, t: u64) {
    r.slots = vec![t]; // <- fires hotpath-alloc (line 16): vec!
}
// lint: hotpath(end)
