//! Cross-language numerical contract: replay `artifacts/golden.json` —
//! concrete input/output vectors recorded by `aot.py` when it lowered each
//! artifact — through the Rust PJRT runtime and assert allclose.
//!
//! This is the single test that pins the whole three-layer stack together:
//! if the Pallas kernels, the JAX model, the HLO-text interchange, or the
//! Rust literal marshalling drift, it fails.

use hts_rl::model::manifest::Manifest;
use hts_rl::runtime::executable::{Input, ModelRuntime};
use hts_rl::util::json::Json;

fn art_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn golden_vectors_replay_through_pjrt() {
    let dir = art_dir();
    let golden_path = dir.join("golden.json");
    if !golden_path.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::new(manifest.clone()).unwrap();
    let golden =
        Json::parse(&std::fs::read_to_string(&golden_path).unwrap()).unwrap();
    let arts: std::collections::BTreeMap<String, _> = manifest
        .artifacts
        .iter()
        .map(|a| (a.file.clone(), a))
        .collect();

    let mut checked = 0;
    for case in golden.get("cases").unwrap().as_arr().unwrap() {
        let fname = case.get("artifact").unwrap().as_str().unwrap();
        let art = arts[fname];
        let meta = manifest
            .artifacts
            .iter()
            .find(|a| a.file == fname)
            .unwrap();
        let _ = meta;
        // input dtypes + shapes come from the manifest artifact entry
        let manifest_entry = {
            let raw = std::fs::read_to_string(dir.join("manifest.json"))
                .unwrap();
            let root = Json::parse(&raw).unwrap();
            root.get("artifacts")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .find(|a| {
                    a.get("file").unwrap().as_str().unwrap() == fname
                })
                .cloned()
                .unwrap()
        };
        let in_specs = manifest_entry.get("inputs").unwrap().as_arr()
            .unwrap().to_vec();
        let inputs_raw = case.get("inputs").unwrap().as_arr().unwrap();
        let dtypes = case.get("in_dtypes").unwrap().as_arr().unwrap();

        // buffers must outlive the Input refs
        let mut f32_bufs: Vec<Vec<f32>> = Vec::new();
        let mut i32_bufs: Vec<Vec<i32>> = Vec::new();
        let mut u32_bufs: Vec<Vec<u32>> = Vec::new();
        let mut kinds: Vec<(u8, usize, Vec<i64>)> = Vec::new();
        for (i, raw) in inputs_raw.iter().enumerate() {
            let dt = dtypes[i].as_str().unwrap();
            let shape: Vec<i64> = in_specs[i]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as i64)
                .collect();
            let vals = raw.as_arr().unwrap();
            match dt {
                "float32" => {
                    f32_bufs.push(
                        vals.iter().map(|v| v.as_f64().unwrap() as f32)
                            .collect());
                    kinds.push((0, f32_bufs.len() - 1, shape));
                }
                "int32" => {
                    i32_bufs.push(
                        vals.iter().map(|v| v.as_f64().unwrap() as i32)
                            .collect());
                    kinds.push((1, i32_bufs.len() - 1, shape));
                }
                "uint32" => {
                    u32_bufs.push(
                        vals.iter().map(|v| v.as_f64().unwrap() as u32)
                            .collect());
                    kinds.push((2, u32_bufs.len() - 1, shape));
                }
                other => panic!("dtype {other}"),
            }
        }
        let inputs: Vec<(Input, &[i64])> = kinds
            .iter()
            .map(|(k, idx, shape)| {
                let inp = match k {
                    0 => Input::F32(&f32_bufs[*idx]),
                    1 => Input::I32(&i32_bufs[*idx]),
                    _ => Input::U32(&u32_bufs[*idx]),
                };
                (inp, shape.as_slice())
            })
            .collect();

        let n_out = case.get("outputs").unwrap().as_arr().unwrap().len();
        let exe = rt.load_artifact(&art.file, n_out).unwrap();
        let outs = exe.run_shaped(&inputs).unwrap();
        for (got, want_raw) in
            outs.iter().zip(case.get("outputs").unwrap().as_arr().unwrap())
        {
            let want = want_raw.as_f32_vec().unwrap();
            assert_eq!(got.len(), want.len(), "{fname}: output arity");
            for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
                let tol = 1e-4f32 + 1e-3 * w.abs();
                assert!(
                    (g - w).abs() <= tol,
                    "{fname}[{i}]: got {g} want {w}"
                );
            }
        }
        checked += 1;
    }
    assert!(checked >= 9, "expected >=9 golden cases, got {checked}");
    println!("golden: {checked} artifact cases replayed OK");
}
