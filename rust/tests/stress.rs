//! Concurrency stress / property tests for the coordination substrates —
//! no artifacts needed, pure L3. These hammer the exact interleavings the
//! HTS-RL determinism argument depends on.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use hts_rl::buffers::{
    ActionBuffer, ObsMsg, RolloutStorage, StateBuffer, StripedSwap,
};
use hts_rl::util::prop;

/// Full executor/actor ping-pong at high contention: every observation
/// must receive exactly the action computed from its own seed, regardless
/// of how many actors race on the state buffer.
#[test]
fn state_action_pingpong_routes_correctly() {
    for &(n_exec, n_actors) in &[(4usize, 1usize), (8, 3), (16, 5)] {
        let steps = 200;
        let sb = Arc::new(StateBuffer::new());
        let ab = Arc::new(ActionBuffer::new(n_exec));
        let mut actors = Vec::new();
        for _ in 0..n_actors {
            let sb = sb.clone();
            let ab = ab.clone();
            actors.push(std::thread::spawn(move || {
                loop {
                    let batch = sb.grab(8);
                    if batch.is_empty() {
                        return;
                    }
                    for m in batch {
                        // "action" = pure function of the seed
                        ab.post(m.slot, (m.seed % 97) as usize);
                    }
                }
            }));
        }
        let mut execs = Vec::new();
        for e in 0..n_exec {
            let sb = sb.clone();
            let ab = ab.clone();
            execs.push(std::thread::spawn(move || {
                for i in 0..steps {
                    let seed = (e as u64) << 32 | i as u64;
                    sb.push(ObsMsg::single(e, vec![0.0], seed));
                    let a = ab.take(e).unwrap();
                    assert_eq!(a, (seed % 97) as usize,
                               "slot {e} step {i} got foreign action");
                }
            }));
        }
        for h in execs {
            h.join().unwrap();
        }
        sb.close();
        ab.close();
        for h in actors {
            h.join().unwrap();
        }
    }
}

/// The two-phase barrier must keep executors and learner in lockstep even
/// when their work durations are adversarially jittered — with each
/// executor writing its private stripe lock-free and the learner
/// gathering at the swap barrier.
#[test]
fn striped_swap_lockstep_under_jitter() {
    prop::check("striped-swap-jitter", 8, |g| {
        let n_exec = g.usize_in(1, 6);
        let iters = 30u64;
        let dp = Arc::new(StripedSwap::new(2, n_exec, 1, n_exec));
        let writes = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for e in 0..n_exec {
            let dp = dp.clone();
            let writes = writes.clone();
            let jitter = g.usize_in(0, 300) as u64;
            handles.push(std::thread::spawn(move || {
                let mut it = 0u64;
                while it < iters {
                    if jitter > 0 {
                        std::thread::sleep(
                            std::time::Duration::from_micros(jitter));
                    }
                    {
                        let mut sh = dp.writer(e);
                        sh.push(e, &[it as f32], 0, 1.0, false);
                        sh.push(e, &[it as f32], 0, 1.0, false);
                        sh.set_last_obs(e, &[it as f32]);
                    }
                    writes.fetch_add(2, Ordering::Relaxed);
                    it = dp.executor_arrive(it).unwrap();
                }
            }));
        }
        let mut view = RolloutStorage::new(2, n_exec, 1);
        let mut it = 0u64;
        while it < iters {
            if it >= 1 {
                // the gathered view must be exactly full — never torn
                assert!(view.is_full(), "iteration {it}: torn gather");
                // every row written by the previous iteration
                assert_eq!(view.total_reward(), (2 * n_exec) as f32);
            }
            assert!(dp.learner_arrive(it));
            // publication window: gather the stripes, like the learner
            dp.gather_and_reset(&mut view);
            it = dp.learner_release(it);
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(writes.load(Ordering::Relaxed), 2 * n_exec as u64 * iters);
    });
}

/// Closing buffers mid-flight must release every blocked party (shutdown
/// can never deadlock).
#[test]
fn shutdown_releases_all_parties() {
    let sb = Arc::new(StateBuffer::new());
    let ab = Arc::new(ActionBuffer::new(4));
    let dp = Arc::new(StripedSwap::new(1, 4, 1, 4));
    let mut handles = Vec::new();
    for e in 0..4 {
        let sb = sb.clone();
        let ab = ab.clone();
        let dp = dp.clone();
        handles.push(std::thread::spawn(move || {
            // park in different blocking calls
            match e % 3 {
                0 => {
                    let _ = ab.take(e);
                }
                1 => {
                    let _ = sb.grab(4);
                }
                _ => {
                    let _ = dp.executor_arrive(0);
                }
            }
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(30));
    sb.close();
    ab.close();
    dp.shutdown();
    for h in handles {
        h.join().unwrap(); // would hang forever on a shutdown bug
    }
}

/// Signature combining is order-independent across executors (XOR) but
/// order-sensitive within one executor's trajectory.
#[test]
fn signature_properties() {
    use hts_rl::coordinator::common::Fnv;
    prop::check("fnv-signature", 64, |g| {
        let n = g.usize_in(1, 20);
        let vals: Vec<u64> =
            (0..n).map(|_| g.usize_in(0, 1 << 30) as u64).collect();
        let hash = |xs: &[u64]| {
            let mut f = Fnv::default();
            for &x in xs {
                f.update(x);
            }
            f.finish()
        };
        let h = hash(&vals);
        assert_eq!(h, hash(&vals), "deterministic");
        if n >= 2 && vals[0] != vals[1] {
            let mut swapped = vals.clone();
            swapped.swap(0, 1);
            assert_ne!(h, hash(&swapped), "order-sensitive");
        }
    });
}
