//! Train HTS-RL(PPO) on a football academy scenario and report the
//! paper's *required time metric* (time to reach eval score 0.4 / 0.8).
//!
//! Usage: cargo run --release --example train_football [-- <scenario>]
//! (default scenario: empty_goal; see `hts-rl list` for all 11.)

use hts_rl::algo::AlgoConfig;
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;

fn main() -> anyhow::Result<()> {
    let scenario = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "empty_goal".to_string());
    let spec = EnvSpec::by_name(&format!("football/{scenario}"))?;
    println!(
        "scenario {scenario}: step-time mean {:.0}µs CoV² {:.2}",
        spec.steptime.mean_us(),
        spec.steptime.cov_squared()
    );
    let mut cfg = RunConfig::new(spec, AlgoConfig::ppo());
    cfg.n_envs = 16;
    cfg.n_actors = 2;
    cfg.seed = 3;
    cfg.eval_every = 4;
    cfg.eval_episodes = 10;
    cfg.stop = StopCond::steps(20_000);

    let r = run(Method::Hts, &cfg)?;
    println!(
        "trained {} steps in {:.1}s ({:.0} SPS), final metric {:.3}",
        r.steps,
        r.wall_s,
        r.sps(),
        r.final_metric()
    );
    for target in [0.4, 0.8] {
        match r.required_time(target) {
            Some(t) => println!(
                "required time to score {target}: {:.2} min", t / 60.0),
            None => println!(
                "score {target} not reached within the step budget ('-')"),
        }
    }
    Ok(())
}
