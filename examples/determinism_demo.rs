//! Determinism demo — paper §4.1's headline system property and the
//! punchline of Tab. 4: with randomness deferred to executors, HTS-RL
//! produces *bit-identical* trajectories no matter how many asynchronous
//! actors serve inference, and across reruns.

use hts_rl::algo::{Algo, AlgoConfig};
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;

fn main() -> anyhow::Result<()> {
    let mut sigs = Vec::new();
    for n_actors in [1usize, 2, 4] {
        let spec = EnvSpec::by_name("catch")?;
        let mut cfg = RunConfig::new(spec, AlgoConfig::a2c(Algo::A2cDelayed));
        cfg.n_envs = 16;
        cfg.n_actors = n_actors;
        cfg.seed = 42;
        cfg.stop = StopCond::updates(10);
        let r = run(Method::Hts, &cfg)?;
        println!(
            "actors={n_actors}: {} steps, signature {:016x}",
            r.steps, r.signature
        );
        sigs.push(r.signature);
    }
    assert!(
        sigs.windows(2).all(|w| w[0] == w[1]),
        "determinism violated!"
    );
    println!("\nall trajectory signatures identical — fully deterministic ✓");
    println!("(compare: the async IMPALA-style driver has no such guarantee)");
    Ok(())
}
