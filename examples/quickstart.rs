//! Quickstart — the end-to-end driver (DESIGN.md "End-to-end validation").
//!
//! Trains HTS-RL(A2C) on GridWorld for a real workload, logging the loss /
//! reward curve, then evaluates the final policy. Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use hts_rl::algo::{Algo, AlgoConfig};
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;

fn main() -> anyhow::Result<()> {
    let spec = EnvSpec::by_name("gridworld")?;
    let mut cfg = RunConfig::new(spec, AlgoConfig::a2c(Algo::A2cDelayed));
    cfg.n_envs = 16;
    cfg.n_actors = 2;
    cfg.seed = 7;
    cfg.eval_every = 40;
    cfg.eval_episodes = 10;
    cfg.stop = StopCond::steps(
        std::env::var("QUICKSTART_STEPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(30_000),
    );

    eprintln!(
        "HTS-RL quickstart: training A2C on GridWorld ({} envs, {} actors, \
         α={} steps)",
        cfg.n_envs,
        cfg.n_actors,
        16 * 5
    );
    let report = run(Method::Hts, &cfg)?;

    println!("\n== training curve (steps, wall_s, reward MA100) ==");
    for (steps, wall_s, reward) in report.curve(20) {
        println!("{steps:>8}  {wall_s:>7.1}s  {reward:>7.3}");
    }
    println!("\n== evaluation curve ==");
    for e in &report.evals {
        println!(
            "update {:>5}  {:>8} steps  {:>6.1}s  score {:>6.3}",
            e.update,
            e.steps,
            e.wall_s,
            e.mean()
        );
    }
    println!(
        "\ntrained {} steps / {} updates in {:.1}s ({:.0} SPS)",
        report.steps,
        report.updates,
        report.wall_s,
        report.sps()
    );
    println!("final metric (last 100 eval episodes): {:.3}",
             report.final_metric());
    println!("trajectory signature: {:016x} (rerun ⇒ identical)",
             report.signature);
    Ok(())
}
