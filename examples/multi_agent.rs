//! Tab. 3 in miniature: multi-agent training on `3_vs_1_with_keeper` —
//! one shared policy controlling 1 vs 3 attackers (both at 12 batch
//! columns so the per-update sample count matches).

use hts_rl::algo::AlgoConfig;
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;

fn main() -> anyhow::Result<()> {
    for (n_agents, n_envs) in [(1usize, 12usize), (3, 4)] {
        // parameterized registry spec: agents= is validated at parse time
        let spec = EnvSpec::by_name(&format!(
            "football/3_vs_1_with_keeper?agents={n_agents}"
        ))?;
        let mut cfg = RunConfig::new(spec, AlgoConfig::ppo());
        cfg.n_envs = n_envs;
        cfg.n_actors = 2;
        cfg.seed = 5;
        cfg.eval_every = 5;
        cfg.stop = StopCond::steps(8_000);
        let r = run(Method::Hts, &cfg)?;
        println!(
            "{n_agents} agent(s) × {n_envs} envs: {} steps in {:.1}s, \
             final score {:.3}",
            r.steps,
            r.wall_s,
            r.final_metric()
        );
    }
    println!("\n(paper Tab. 3: controlling 3 attackers scores higher than 1)");

    // The cheap multi-agent workload (ISSUE 4): cooperative gridworld
    // goal capture — same pool/plane multi-agent path, ~zero engine cost.
    let spec = EnvSpec::by_name("gridworld_team/gather?agents=2,slip=0.1")?;
    let mut cfg = RunConfig::new(spec, AlgoConfig::ppo());
    cfg.n_envs = 8;
    cfg.n_actors = 2;
    cfg.seed = 5;
    cfg.eval_every = 5;
    cfg.stop = StopCond::steps(8_000);
    let r = run(Method::Hts, &cfg)?;
    println!(
        "gridworld_team 2 agents × 8 envs: {} steps in {:.1}s, final \
         score {:.3}",
        r.steps,
        r.wall_s,
        r.final_metric()
    );
    Ok(())
}
