//! Fig. 4(right) in miniature: SPS vs number of environments on the
//! slowest, most variable scenario (`counterattack_hard`), HTS-RL(PPO)
//! against the step-synchronous PPO baseline — plus the replica-pool
//! column (K = 4 env replicas multiplexed per executor thread, a quarter
//! of the threads, bit-identical trajectories; DESIGN.md §6).

use hts_rl::algo::AlgoConfig;
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;

fn main() -> anyhow::Result<()> {
    println!(
        "{:>6}  {:>12}  {:>14}  {:>12}  {:>8}",
        "#envs", "HTS-PPO SPS", "HTS K=4 SPS", "sync SPS", "speedup"
    );
    for n_envs in [4usize, 8, 16] {
        let spec = EnvSpec::by_name("football/counterattack_hard")?;
        let mut cfg = RunConfig::new(spec, AlgoConfig::ppo());
        cfg.n_envs = n_envs;
        cfg.n_actors = 2;
        cfg.stop = StopCond::steps(150 * n_envs as u64);
        let hts = run(Method::Hts, &cfg)?;
        let mut pooled_cfg = cfg.clone();
        pooled_cfg.replicas_per_executor = 4;
        let pooled = run(Method::Hts, &pooled_cfg)?;
        let sync = run(Method::Sync, &cfg)?;
        assert_eq!(
            hts.signature, pooled.signature,
            "pooling must not change trajectories"
        );
        println!(
            "{:>6}  {:>12.0}  {:>14.0}  {:>12.0}  {:>7.2}x",
            n_envs,
            hts.sps(),
            pooled.sps(),
            sync.sps(),
            hts.sps() / sync.sps()
        );
    }
    println!(
        "\nHTS-RL throughput scales ~linearly in #envs; the per-step-\n\
         synchronized baseline pays E[max] every step (paper Claim 1).\n\
         The K=4 column does it with a quarter of the executor threads\n\
         and the exact same run signature."
    );
    Ok(())
}
