//! Fig. 4(right) in miniature: SPS vs number of environments on the
//! slowest, most variable scenario (`counterattack_hard`), HTS-RL(PPO)
//! against the step-synchronous PPO baseline.

use hts_rl::algo::AlgoConfig;
use hts_rl::coordinator::{run, Method, RunConfig, StopCond};
use hts_rl::envs::EnvSpec;

fn main() -> anyhow::Result<()> {
    println!("{:>6}  {:>12}  {:>12}  {:>8}", "#envs", "HTS-PPO SPS",
             "sync SPS", "speedup");
    for n_envs in [2usize, 4, 8, 16] {
        let spec = EnvSpec::by_name("football/counterattack_hard")?;
        let mut cfg = RunConfig::new(spec, AlgoConfig::ppo());
        cfg.n_envs = n_envs;
        cfg.n_actors = 2;
        cfg.stop = StopCond::steps(150 * n_envs as u64);
        let hts = run(Method::Hts, &cfg)?;
        let sync = run(Method::Sync, &cfg)?;
        println!(
            "{:>6}  {:>12.0}  {:>12.0}  {:>7.2}x",
            n_envs,
            hts.sps(),
            sync.sps(),
            hts.sps() / sync.sps()
        );
    }
    println!("\nHTS-RL throughput scales ~linearly in #envs; the per-step-\n\
              synchronized baseline pays E[max] every step (paper Claim 1).");
    Ok(())
}
